//! `tetris plan` — the autotuning **Pattern Mapper** (paper §4: the
//! polymorphic tiling tetrominoes bridge "different hardware
//! architectures and various application contexts with a perfect
//! spatial and temporal tessellation *automatically*").
//!
//! The rest of the stack exposes every knob — engine, thread count,
//! tile width, fused block depth Tb — and the paper's thesis is that a
//! cloud user should never have to turn any of them.  This subsystem
//! closes that gap:
//!
//! * [`fingerprint`] — identifies the machine (logical cores, a
//!   cache-line probe, a ~100 ms micro-calibration of stencil
//!   throughput) so plans are keyed to hardware, not hope;
//! * [`cost`] — an α+β-style analytic model that prunes the
//!   configuration space before anything is timed;
//! * [`search`] — the cost-pruned timed search over `(engine, threads,
//!   Tb, tile)` on shrunken proxy grids, emitting a versioned [`Plan`];
//! * [`store`] — the persistent JSON-lines plan store
//!   (`~/.tetris/plans.jsonl` by default): tuning cost is paid once per
//!   `(machine, bench, boundary, shape-bucket)`, not per job.
//!
//! Consumers: `tetris tune` runs/refreshes the search, `--engine auto`
//! on `run`/`hetero` resolves through the store ([`resolve_auto`]), and
//! `serve` sessions adopt the stored plan at creation and write back
//! improved plans observed from live runs.

pub mod cost;
pub mod fingerprint;
pub mod search;
pub mod store;

pub use cost::CostModel;
pub use fingerprint::Fingerprint;
pub use search::{search, search_with, Candidate, SearchConfig};
pub use store::PlanStore;

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use crate::engine::Engine;

/// Plan record format version (bumped on incompatible field changes;
/// newer readers keep accepting older records).
pub const PLAN_VERSION: u64 = 1;

/// Resolve an engine name against **both** registries — the optimized
/// engines and the Fig-13 baselines.  Every CLI surface and the plan
/// search accept the union.
pub fn resolve_engine(name: &str, threads: usize) -> Option<Box<dyn Engine>> {
    crate::engine::by_name(name, threads.max(1)).or_else(|| crate::baselines::by_name(name))
}

/// Power-of-two shape bucket: each dim rounds to the nearest 2^k.  Plans
/// are keyed on the bucket, not the exact shape, so a 500x500 job reuses
/// the 512x512 plan — stencil throughput is a smooth function of size,
/// and per-exact-shape keys would turn the store into a cache that never
/// hits.
pub fn shape_bucket(shape: &[usize]) -> Vec<usize> {
    shape
        .iter()
        .map(|&n| {
            let l = (n.max(1) as f64).log2().round().max(0.0) as u32;
            1usize << l.min(62)
        })
        .collect()
}

/// One tuned execution configuration for a `(fingerprint, bench,
/// boundary kind, shape bucket)` key — what `--engine auto` resolves to.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub version: u64,
    /// [`Fingerprint::id`] of the machine the plan was tuned on.
    pub fingerprint: String,
    pub bench: String,
    /// Boundary family (`dirichlet`/`neumann`/`periodic`) — wall values
    /// don't change the cost profile, so plans key on the kind.
    pub boundary: String,
    /// [`shape_bucket`] of the tuned shape.
    pub bucket: Vec<usize>,
    /// Winning engine (engine or baseline registry name).
    pub engine: String,
    pub threads: usize,
    /// Fused steps per block.
    pub tb: usize,
    /// Tile-width override for the tessellation family (None = heuristic).
    pub tile_w: Option<usize>,
    /// §5.3 leader-loop preference for scheduler-mode runs: `Some(true)`
    /// = pipelined (overlap halo exchange with compute), `Some(false)` =
    /// serial, `None` = let the scheduler's `auto` heuristic decide.
    /// Searched by the tuner's overlap probe; bit-exact either way.
    pub overlap: Option<bool>,
    /// Worker-grid shape `(wy, wx)` for scheduler-mode runs: `Some` when
    /// the planner chose a 2-D tile grid over 1-D row spans (the
    /// perimeter-over-area prior — see [`cost::choose_grid`]), `None` =
    /// dim-0 spans only.  Runs with fewer workers than `wy*wx` fall back
    /// to 1-D.
    pub grid: Option<(usize, usize)>,
    /// Throughput observed when the plan was selected (GStencils/s on
    /// the proxy grid for tuned plans, on the real run for observed ones).
    pub gsps: f64,
    /// Provenance: `tuned` (search), `warm-start` (nearest-bucket
    /// adoption), `observed` (written back by a live serve session).
    pub source: String,
    /// Search seed (trial ordering / tie-break reproducibility).
    pub seed: u64,
}

impl Plan {
    /// Store key: plans are unique per machine/bench/boundary/bucket,
    /// latest record wins.
    pub fn key(&self) -> String {
        format!("{}|{}|{}|{:?}", self.fingerprint, self.bench, self.boundary, self.bucket)
    }

    /// The plan as a search candidate (to instantiate its engine).
    pub fn candidate(&self) -> Candidate {
        Candidate {
            engine: self.engine.clone(),
            threads: self.threads.max(1),
            tb: self.tb.max(1),
            tile_w: self.tile_w,
        }
    }

    /// Deterministic single-line JSON (keys sort lexicographically via
    /// the `BTreeMap` printer) — the golden-file tests are byte-stable.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("v".into(), Json::Num(self.version as f64));
        m.insert("fp".into(), Json::Str(self.fingerprint.clone()));
        m.insert("bench".into(), Json::Str(self.bench.clone()));
        m.insert("boundary".into(), Json::Str(self.boundary.clone()));
        m.insert(
            "bucket".into(),
            Json::Arr(self.bucket.iter().map(|&x| Json::Num(x as f64)).collect()),
        );
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("threads".into(), Json::Num(self.threads as f64));
        m.insert("tb".into(), Json::Num(self.tb as f64));
        if let Some(w) = self.tile_w {
            m.insert("tile_w".into(), Json::Num(w as f64));
        }
        if let Some(o) = self.overlap {
            m.insert("overlap".into(), Json::Bool(o));
        }
        if let Some((wy, wx)) = self.grid {
            m.insert(
                "grid".into(),
                Json::Arr(vec![Json::Num(wy as f64), Json::Num(wx as f64)]),
            );
        }
        m.insert("gsps".into(), Json::Num(self.gsps));
        m.insert("source".into(), Json::Str(self.source.clone()));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        Json::Obj(m)
    }

    /// Tolerant decode: unknown keys are ignored (a newer tetris may
    /// write fields this build does not know), every non-identifying
    /// field has a default.
    pub fn from_json(v: &Json) -> Result<Plan> {
        v.as_obj().context("plan must be a JSON object")?;
        Ok(Plan {
            version: v.at(&["v"]).as_u64().unwrap_or(1),
            fingerprint: v.at(&["fp"]).as_str().unwrap_or("").to_string(),
            bench: v.at(&["bench"]).as_str().context("plan missing bench")?.to_string(),
            boundary: v.at(&["boundary"]).as_str().unwrap_or("dirichlet").to_string(),
            bucket: v.get("bucket").and_then(|b| b.usize_vec()).context("plan missing bucket")?,
            engine: v.at(&["engine"]).as_str().context("plan missing engine")?.to_string(),
            threads: v.at(&["threads"]).as_usize().unwrap_or(1).max(1),
            tb: v.at(&["tb"]).as_usize().unwrap_or(1).max(1),
            tile_w: v.get("tile_w").and_then(|t| t.as_usize()),
            overlap: v.get("overlap").and_then(|o| o.as_bool()),
            grid: v
                .get("grid")
                .and_then(|g| g.usize_vec())
                .filter(|v| v.len() == 2 && v[0] >= 1 && v[1] >= 1)
                .map(|v| (v[0], v[1])),
            gsps: v.at(&["gsps"]).as_f64().unwrap_or(0.0),
            source: v.at(&["source"]).as_str().unwrap_or("tuned").to_string(),
            seed: v.at(&["seed"]).as_u64().unwrap_or(0),
        })
    }

    pub fn parse_line(line: &str) -> Result<Plan> {
        let v = Json::parse(line.trim()).context("plan parse")?;
        Plan::from_json(&v)
    }
}

/// How [`resolve_auto`] arrived at its plan.
#[derive(Clone, Debug)]
pub struct Resolution {
    pub plan: Plan,
    /// Exact store hit — no search ran.
    pub cached: bool,
    /// Nearest-bucket warm start — adopted a neighbour, no search ran.
    pub warmed: bool,
}

/// The `--engine auto` resolution ladder:
///
/// 1. exact `(fingerprint, bench, boundary, bucket)` store hit — use it,
///    search nothing (a `plan: cached` run);
/// 2. nearest-bucket warm start — a plan for the same machine, bench and
///    boundary at a different size transfers (throughput is smooth in
///    shape); persist it under the exact key so step 1 hits next time;
/// 3. cold — run the budgeted calibrated search and persist the winner.
///
/// Both store probes share ONE loaded snapshot — the ladder reads the
/// store file once per resolution, not once per probe.
pub fn resolve_auto(
    store: &PlanStore,
    fp: &Fingerprint,
    bench: &str,
    boundary_kind: &str,
    shape: &[usize],
    steps_hint: usize,
    cfg: &SearchConfig,
) -> Result<Resolution> {
    let snapshot = store.load();
    if let Some(plan) = PlanStore::lookup_in(&snapshot, fp, bench, boundary_kind, shape) {
        return Ok(Resolution { plan, cached: true, warmed: false });
    }
    if let Some(mut plan) = PlanStore::lookup_near_in(&snapshot, fp, bench, boundary_kind, shape) {
        plan.bucket = shape_bucket(shape);
        plan.fingerprint = fp.id();
        plan.source = "warm-start".into();
        store.append(&plan)?;
        return Ok(Resolution { plan, cached: false, warmed: true });
    }
    let plan = search(bench, boundary_kind, shape, steps_hint, fp, cfg)?;
    store.append(&plan)?;
    Ok(Resolution { plan, cached: false, warmed: false })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_bucket_rounds_to_nearest_pow2() {
        assert_eq!(shape_bucket(&[512, 512]), vec![512, 512]);
        assert_eq!(shape_bucket(&[500, 24]), vec![512, 32]);
        assert_eq!(shape_bucket(&[1]), vec![1]);
        assert_eq!(shape_bucket(&[96]), vec![128]);
        assert_eq!(shape_bucket(&[2, 5, 6]), vec![2, 4, 8]);
    }

    #[test]
    fn plan_round_trips_and_tolerates_unknown_fields() {
        let p = Plan {
            version: PLAN_VERSION,
            fingerprint: "c8/l64/g2".into(),
            bench: "heat2d".into(),
            boundary: "periodic".into(),
            bucket: vec![512, 512],
            engine: "tetris-cpu".into(),
            threads: 8,
            tb: 4,
            tile_w: Some(64),
            overlap: Some(true),
            grid: Some((2, 2)),
            gsps: 1.25,
            source: "tuned".into(),
            seed: 42,
        };
        let line = p.to_json().to_string();
        assert!(!line.contains('\n'));
        assert_eq!(Plan::parse_line(&line).unwrap(), p);
        // a record from the future parses, extra keys ignored
        let future = line.replacen('{', "{\"zeta\":true,", 1);
        assert_eq!(Plan::parse_line(&future).unwrap(), p);
        // tile_w is omitted when None and comes back as None
        let q = Plan { tile_w: None, ..p.clone() };
        let qline = q.to_json().to_string();
        assert!(!qline.contains("tile_w"));
        assert_eq!(Plan::parse_line(&qline).unwrap(), q);
        // overlap: omitted when None (pre-overlap records stay valid),
        // round-trips both booleans
        let r = Plan { overlap: None, ..p.clone() };
        let rline = r.to_json().to_string();
        assert!(!rline.contains("overlap"));
        assert_eq!(Plan::parse_line(&rline).unwrap(), r);
        let s = Plan { overlap: Some(false), ..p.clone() };
        assert_eq!(Plan::parse_line(&s.to_json().to_string()).unwrap(), s);
        // grid: omitted when None (pre-grid records stay valid), and a
        // degenerate/malformed stored grid decodes as None
        let g = Plan { grid: None, ..p.clone() };
        let gline = g.to_json().to_string();
        assert!(!gline.contains("grid"));
        assert_eq!(Plan::parse_line(&gline).unwrap(), g);
        let bad = gline.replacen('{', "{\"grid\":[0,2],", 1);
        assert_eq!(Plan::parse_line(&bad).unwrap().grid, None);
        let bad = gline.replacen('{', "{\"grid\":[2],", 1);
        assert_eq!(Plan::parse_line(&bad).unwrap().grid, None);
    }

    #[test]
    fn plan_rejects_records_missing_identity() {
        assert!(Plan::parse_line(r#"{"engine":"simd","bucket":[8]}"#).is_err());
        assert!(Plan::parse_line(r#"{"bench":"heat2d","bucket":[8]}"#).is_err());
        assert!(Plan::parse_line(r#"{"bench":"heat2d","engine":"simd"}"#).is_err());
        assert!(Plan::parse_line("[1,2]").is_err());
        assert!(Plan::parse_line("{nope").is_err());
    }

    #[test]
    fn resolve_engine_accepts_both_registries_and_auto_is_not_an_engine() {
        assert!(resolve_engine("tetris-cpu", 2).is_some());
        assert!(resolve_engine("an5d", 1).is_some(), "baselines must resolve too");
        assert!(resolve_engine("auto", 1).is_none(), "auto is a resolution mode, not an engine");
        assert!(resolve_engine("bogus", 1).is_none());
    }

    fn temp_store(tag: &str) -> PlanStore {
        let path = std::env::temp_dir()
            .join(format!("tetris-test-{tag}-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        PlanStore::open(path)
    }

    /// Acceptance: first resolution on an empty store calibrates and
    /// persists; the second hits the stored plan without re-searching;
    /// a different bucket warm-starts from the neighbour and then also
    /// becomes an exact hit.
    #[test]
    fn resolve_auto_persists_then_hits_cache_then_warm_starts() {
        let store = temp_store("resolve-auto");
        let fp = Fingerprint::synthetic(2, 64, 0.5);
        let cfg = SearchConfig { budget_ms: 120, seed: 7, shortlist: 3, max_proxy_cells: 1024 };
        let a = resolve_auto(&store, &fp, "heat1d", "dirichlet", &[64], 8, &cfg).unwrap();
        assert!(!a.cached && !a.warmed);
        assert_eq!(a.plan.bucket, vec![64]);
        assert!(a.plan.candidate().build().is_some(), "plan engine must resolve");

        let b = resolve_auto(&store, &fp, "heat1d", "dirichlet", &[64], 8, &cfg).unwrap();
        assert!(b.cached, "second resolution must hit the store, not re-search");
        assert_eq!(a.plan, b.plan);

        // bucket(200) = 256 != 64: nearest-bucket warm start
        let c = resolve_auto(&store, &fp, "heat1d", "dirichlet", &[200], 8, &cfg).unwrap();
        assert!(c.warmed && !c.cached);
        assert_eq!(c.plan.engine, a.plan.engine);
        assert_eq!(c.plan.bucket, vec![256]);
        assert_eq!(c.plan.source, "warm-start");
        let d = resolve_auto(&store, &fp, "heat1d", "dirichlet", &[200], 8, &cfg).unwrap();
        assert!(d.cached, "warm-started plan must be an exact hit afterwards");

        let _ = std::fs::remove_file(&store.path);
    }

    /// A foreign fingerprint must not be served this machine's plans —
    /// and must not poison them either.
    #[test]
    fn resolve_auto_ignores_foreign_fingerprints() {
        let store = temp_store("resolve-foreign");
        let ours = Fingerprint::synthetic(2, 64, 0.5);
        let cfg = SearchConfig { budget_ms: 120, seed: 7, shortlist: 2, max_proxy_cells: 1024 };
        let a = resolve_auto(&store, &ours, "heat1d", "dirichlet", &[64], 8, &cfg).unwrap();
        assert!(!a.cached);
        // same key shape, wildly different machine: no hit, fresh search
        let theirs = Fingerprint::synthetic(96, 128, 500.0);
        let b = resolve_auto(&store, &theirs, "heat1d", "dirichlet", &[64], 8, &cfg).unwrap();
        assert!(!b.cached && !b.warmed, "foreign plans must be ignored, not misapplied");
        let _ = std::fs::remove_file(&store.path);
    }
}
