//! Analytic pruning model for the plan search.
//!
//! Timing every `(engine, threads, Tb, tile)` combination would blow any
//! tuning budget, so the search first ranks the space with a coarse
//! closed-form estimate built from the machine's micro-calibration and
//! the same α+β accounting the coordinator uses ([`CommModel`]):
//!
//! * compute: `cells × steps × tap-penalty` over the calibrated
//!   GStencils/s, scaled by a per-engine throughput prior and an
//!   Amdahl-style parallel efficiency;
//! * temporal fusion: the extended/core volume ratio charges deeper Tb
//!   for its ghost redundancy;
//! * per-block overhead: one α-scale launch per dispatched block plus
//!   the O(surface) ghost-ring refresh at β — the term that makes small
//!   grids favour deep Tb and huge grids shallow Tb.
//!
//! The estimates only need to *rank* candidates well enough that the
//! timed trials see the right shortlist; the trials have the final word.

use crate::coordinator::CommModel;
use crate::stencil::StencilSpec;

use super::fingerprint::Fingerprint;
use super::search::Candidate;

/// Single-thread throughput prior relative to the calibrated `simd`
/// engine, and whether the engine scales with the thread knob.
pub fn engine_prior(name: &str) -> (f64, bool) {
    match name {
        "naive" => (0.12, false),
        "autovec" => (0.55, false),
        "simd" => (1.0, false),
        "tiled" => (0.95, false),
        "tessellate" => (0.5, false),
        "tetris-cpu" => (1.05, true),
        "tetris-wave" => (1.0, true),
        "datareorg" => (0.45, false),
        "pluto" => (0.7, false),
        "folding" => (0.8, false),
        "brick" => (0.75, false),
        "an5d" => (0.85, false),
        _ => (0.4, false),
    }
}

/// The pruning model: calibrated machine speed + α/β overheads.
pub struct CostModel {
    pub comm: CommModel,
    /// Calibrated single-thread `simd` GStencils/s (heat2d, 5 taps).
    pub calib_gsps: f64,
}

impl CostModel {
    pub fn from_fingerprint(fp: &Fingerprint) -> CostModel {
        CostModel { comm: CommModel::default(), calib_gsps: fp.calib_gsps.max(1e-3) }
    }

    /// Estimated wall seconds to advance `core` by `total_steps` under
    /// candidate `c`.  Deterministic in its inputs (the search's
    /// reproducibility leans on this).
    pub fn estimate_secs(
        &self,
        spec: &StencilSpec,
        core: &[usize],
        total_steps: usize,
        c: &Candidate,
    ) -> f64 {
        let cells: f64 = core.iter().product::<usize>() as f64;
        let (factor, scales) = engine_prior(&c.engine);
        let threads = if scales { c.threads.max(1) as f64 } else { 1.0 };
        // Amdahl-ish efficiency: ~8% serial per extra thread.
        let speedup = threads / (1.0 + 0.08 * (threads - 1.0));
        // Calibration ran the 5-tap heat2d; wider footprints cost
        // proportionally more per cell.
        let tap_penalty = spec.points() as f64 / 5.0;
        let base = cells * total_steps as f64 * tap_penalty
            / (self.calib_gsps * 1e9 * factor * speedup);
        // Fused-block ghost redundancy: extended/core volume ratio.
        let halo = spec.radius * c.tb.max(1);
        let ext_ratio: f64 =
            core.iter().map(|&n| (n + 2 * halo) as f64 / n.max(1) as f64).product();
        // Per-block launch (α per thread team) + ghost-ring refresh (β
        // over the ring surface).
        let blocks = (total_steps as f64 / c.tb.max(1) as f64).ceil().max(1.0);
        let ext_cells: f64 = core.iter().map(|&n| (n + 2 * halo) as f64).product();
        let ring = (ext_cells - cells).max(0.0);
        base * ext_ratio + blocks * (self.comm.alpha * (1.0 + threads) + ring * 8.0 * self.comm.beta)
    }

    /// Perimeter-over-area prior: pick the `Wy×Wx` worker-grid shape for
    /// `workers` tiles over `shape` that minimizes per-block halo
    /// **bytes** (the tile perimeter), enumerating the factorizations of
    /// `workers` laid out as even splits and accounting edges + corners
    /// with [`grid_exchanges`].  Bytes rank first — under the §5.3
    /// pipelined loop the extra corner-message launches overlap with
    /// compute, the bandwidth doesn't — with fewer messages and then
    /// smaller `wy` as tie-breaks.  `None` for 1-D fields, a single
    /// worker, or when no factorization fits the domain (an axis
    /// shorter than its worker count).
    ///
    /// [`grid_exchanges`]: crate::coordinator::comm::grid_exchanges
    pub fn choose_grid(
        &self,
        workers: usize,
        shape: &[usize],
        halo: usize,
    ) -> Option<(usize, usize)> {
        if workers < 2 || shape.len() < 2 {
            return None;
        }
        let rest2: usize = shape[2..].iter().product::<usize>().max(1);
        let spans_of = |widths: Vec<usize>| -> Vec<(usize, usize)> {
            let mut at = 0usize;
            widths
                .into_iter()
                .map(|w| {
                    let s = at;
                    at += w;
                    (s, at)
                })
                .collect()
        };
        let mut best: Option<((usize, usize), (usize, usize, usize))> = None;
        for wy in 1..=workers {
            if workers % wy != 0 || wy > shape[1] {
                continue;
            }
            let wx = workers / wy;
            if wx > shape[0] {
                continue;
            }
            let rows = spans_of(crate::coordinator::partition::even_split(shape[0], wx));
            let bands = spans_of(crate::coordinator::partition::even_split(shape[1], wy));
            let ex = crate::coordinator::comm::grid_exchanges(&rows, &bands, halo, rest2, false);
            let key = (ex.iter().sum::<usize>(), ex.len(), wy);
            if best.as_ref().map_or(true, |(_, k)| key < *k) {
                best = Some(((wy, wx), key));
            }
        }
        best.map(|(g, _)| g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec;

    fn model() -> CostModel {
        CostModel::from_fingerprint(&Fingerprint::synthetic(8, 64, 1.0))
    }

    fn cand(engine: &str, threads: usize, tb: usize) -> Candidate {
        Candidate { engine: engine.into(), threads, tb, tile_w: None }
    }

    #[test]
    fn estimates_are_positive_and_rank_engines() {
        let m = model();
        let s = spec::get("heat2d").unwrap();
        let naive = m.estimate_secs(&s, &[256, 256], 16, &cand("naive", 1, 2));
        let simd = m.estimate_secs(&s, &[256, 256], 16, &cand("simd", 1, 2));
        assert!(naive > 0.0 && simd > 0.0);
        assert!(naive > simd, "the prior must rank naive behind simd");
    }

    #[test]
    fn threads_help_scaling_engines_only() {
        let m = model();
        let s = spec::get("heat2d").unwrap();
        let t1 = m.estimate_secs(&s, &[512, 512], 16, &cand("tetris-cpu", 1, 4));
        let t8 = m.estimate_secs(&s, &[512, 512], 16, &cand("tetris-cpu", 8, 4));
        assert!(t8 < t1, "tetris-cpu must profit from threads: {t8} vs {t1}");
        let s1 = m.estimate_secs(&s, &[512, 512], 16, &cand("simd", 1, 4));
        let s8 = m.estimate_secs(&s, &[512, 512], 16, &cand("simd", 8, 4));
        assert!(s8 >= s1, "thread-blind engines must not fake a speedup");
    }

    #[test]
    fn deep_tb_wins_on_launch_bound_grids() {
        // Small 1-D grid: per-block launches dominate, so Tb=8 must beat
        // Tb=1 despite the ghost redundancy.
        let m = model();
        let s = spec::get("heat1d").unwrap();
        let shallow = m.estimate_secs(&s, &[4096], 16, &cand("simd", 1, 1));
        let deep = m.estimate_secs(&s, &[4096], 16, &cand("simd", 1, 8));
        assert!(deep < shallow, "{deep} !< {shallow}");
    }

    #[test]
    fn ghost_redundancy_punishes_deep_tb_on_wide_footprints() {
        // box2d25p (radius 2), single thread: Tb=8 means a 16-cell halo
        // on a 64-cell core (2.25x the compute volume) — the redundancy
        // term must dwarf the per-block launch saving.
        let m = model();
        let s = spec::get("box2d25p").unwrap();
        let shallow = m.estimate_secs(&s, &[64, 64], 16, &cand("tetris-cpu", 1, 2));
        let deep = m.estimate_secs(&s, &[64, 64], 16, &cand("tetris-cpu", 1, 8));
        assert!(deep > shallow, "{deep} !> {shallow}");
    }

    #[test]
    fn choose_grid_prefers_square_tiles_on_square_domains() {
        // 64×64 at W=4: the 2×2 grid's perimeter (edges + corners) ships
        // fewer bytes than the 1×4 flat split's three full-width links.
        let m = model();
        assert_eq!(m.choose_grid(4, &[64, 64], 2), Some((2, 2)));
        // W=9 on a square: 3×3
        assert_eq!(m.choose_grid(9, &[81, 81], 1), Some((3, 3)));
    }

    #[test]
    fn choose_grid_splits_the_long_axis_on_flat_domains() {
        let m = model();
        assert_eq!(m.choose_grid(4, &[256, 8], 2), Some((1, 4)));
        assert_eq!(m.choose_grid(4, &[8, 256], 2), Some((4, 1)));
    }

    #[test]
    fn choose_grid_degenerate_cases() {
        let m = model();
        assert_eq!(m.choose_grid(1, &[64, 64], 2), None, "one worker has no grid to pick");
        assert_eq!(m.choose_grid(4, &[4096], 2), None, "1-D fields have no column axis");
        // prime W on a square domain: both 1×5 and 5×1 ship the same
        // bytes; ties break toward fewer bands
        assert_eq!(m.choose_grid(5, &[64, 64], 2), Some((1, 5)));
        // no factorization fits a 4-cell-wide domain with 8 workers/axis
        assert_eq!(m.choose_grid(64, &[4, 4], 1), None);
    }

    #[test]
    fn estimate_is_deterministic() {
        let m = model();
        let s = spec::get("heat3d").unwrap();
        let c = cand("tetris-wave", 4, 2);
        let a = m.estimate_secs(&s, &[64, 64, 64], 8, &c);
        let b = m.estimate_secs(&s, &[64, 64, 64], 8, &c);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
