//! Hardware fingerprinting: the identity half of a plan-store key.
//!
//! A plan tuned on one machine is noise on another, so every [`Plan`]
//! records where it was tuned: logical core count, a cache-line probe,
//! and a ~100 ms micro-calibration (single-thread `simd` GStencils/s on
//! a small heat2d proxy grid).  Matching is deliberately coarse —
//! exact cores plus a calibration throughput within ~3x — because the
//! calibration jitters run to run and an over-precise fingerprint would
//! orphan every stored plan.  The cache-line figure is recorded for
//! diagnostics but not matched (hardware prefetchers make the probe the
//! least stable of the three signals).
//!
//! [`Plan`]: super::Plan

use std::time::{Duration, Instant};

use crate::engine::Engine;
use crate::stencil::{spec, Field};

/// What the machine looks like to the Pattern Mapper.
#[derive(Clone, Debug)]
pub struct Fingerprint {
    /// Logical cores (`std::thread::available_parallelism`).
    pub cores: usize,
    /// Probed cache-line size in bytes (64 when the probe is inconclusive).
    pub cache_line: usize,
    /// Micro-calibration: single-thread `simd` heat2d GStencils/s.
    pub calib_gsps: f64,
}

impl Fingerprint {
    /// Probe the current machine.  `budget_ms` bounds the calibration
    /// run (~half is spent calibrating, the probe costs a few ms).
    pub fn detect(budget_ms: u64) -> Fingerprint {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Fingerprint {
            cores,
            cache_line: cache_line_probe(),
            calib_gsps: calibrate(budget_ms),
        }
    }

    /// A fingerprint with given figures — tests and cost-model-only use.
    pub fn synthetic(cores: usize, cache_line: usize, calib_gsps: f64) -> Fingerprint {
        Fingerprint { cores: cores.max(1), cache_line, calib_gsps }
    }

    /// Stable identity string recorded in plans:
    /// `c<cores>/l<cache_line>/g<bucket>` where the bucket is the
    /// calibration throughput in half-octaves (`round(2*log2(gsps))`).
    pub fn id(&self) -> String {
        format!("c{}/l{}/g{}", self.cores, self.cache_line, gsps_bucket(self.calib_gsps))
    }

    /// Whether a stored plan's fingerprint describes this machine:
    /// same core count and a calibration bucket within ±3 half-octaves
    /// (~2.8x throughput) — wide enough to absorb calibration jitter on
    /// a loaded machine, narrow enough that a laptop never adopts a
    /// 96-core server's plan.
    pub fn matches(&self, id: &str) -> bool {
        match parse_id(id) {
            Some((cores, _line, g)) => {
                cores == self.cores && (g - gsps_bucket(self.calib_gsps)).abs() <= 3
            }
            None => false,
        }
    }
}

/// Calibration throughput in half-octave buckets.
fn gsps_bucket(gsps: f64) -> i64 {
    (2.0 * gsps.max(1e-6).log2()).round() as i64
}

fn parse_id(id: &str) -> Option<(usize, usize, i64)> {
    let mut it = id.split('/');
    let cores = it.next()?.strip_prefix('c')?.parse().ok()?;
    let line = it.next()?.strip_prefix('l')?.parse().ok()?;
    let g = it.next()?.strip_prefix('g')?.parse().ok()?;
    if it.next().is_some() {
        return None;
    }
    Some((cores, line, g))
}

/// Single-thread `simd` heat2d throughput on a 64x64 proxy — the
/// machine-speed scalar every cost-model estimate hangs off.
fn calibrate(budget_ms: u64) -> f64 {
    let s = spec::get("heat2d").expect("heat2d spec");
    let eng = crate::engine::by_name("simd", 1).expect("simd engine");
    const N: usize = 64;
    let tb = 2;
    let halo = s.radius * tb;
    let mut cur = Field::random(&[N + 2 * halo, N + 2 * halo], 0xF17);
    let deadline = Instant::now() + Duration::from_millis(budget_ms.max(10) / 2);
    let t0 = Instant::now();
    let mut steps = 0usize;
    loop {
        let out = eng.block(&s, &cur, tb);
        cur = out.pad(halo, 0.0);
        steps += tb;
        if steps >= 2 * tb && Instant::now() >= deadline {
            break;
        }
    }
    std::hint::black_box(&cur);
    (N * N * steps) as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e9
}

/// Strided-touch cache-line probe over a buffer well past L2: per-touch
/// cost roughly doubles with the stride while several touches share a
/// line, then flattens once every touch lands on a fresh line.  The
/// first stride whose successor stops near-doubling is the line size.
/// Median of 3 passes per stride; 64 on an inconclusive (non-flattening)
/// curve.
fn cache_line_probe() -> usize {
    let mut buf = vec![1u8; 1 << 22];
    let strides = [16usize, 32, 64, 128, 256];
    let _ = probe_pass(&mut buf, 64); // warm the buffer in
    let mut per_touch = Vec::with_capacity(strides.len());
    for &s in &strides {
        let mut samples = [
            probe_pass(&mut buf, s),
            probe_pass(&mut buf, s),
            probe_pass(&mut buf, s),
        ];
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        per_touch.push(samples[1]);
    }
    for i in 0..strides.len() - 1 {
        if per_touch[i + 1] < per_touch[i] * 1.5 {
            return strides[i];
        }
    }
    64
}

fn probe_pass(buf: &mut [u8], stride: usize) -> f64 {
    let t0 = Instant::now();
    let mut i = 0usize;
    while i < buf.len() {
        buf[i] = buf[i].wrapping_add(1);
        i += stride;
    }
    std::hint::black_box(&*buf);
    t0.elapsed().as_secs_f64() / (buf.len() / stride) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_round_trips_through_matches() {
        let fp = Fingerprint::synthetic(8, 64, 1.0);
        assert_eq!(fp.id(), "c8/l64/g0");
        assert!(fp.matches(&fp.id()));
        // negative buckets (slow machines) survive the id grammar
        let slow = Fingerprint::synthetic(2, 64, 0.1);
        assert!(slow.id().contains("/g-"), "{}", slow.id());
        assert!(slow.matches(&slow.id()));
    }

    #[test]
    fn matches_tolerates_calibration_jitter_but_not_machines() {
        let fp = Fingerprint::synthetic(8, 64, 1.0);
        // within ~2x: same machine on a noisy day
        assert!(fp.matches(&Fingerprint::synthetic(8, 128, 1.8).id()));
        // different core count: a different machine, full stop
        assert!(!fp.matches(&Fingerprint::synthetic(16, 64, 1.0).id()));
        // same cores but ~20x the throughput: not this machine either
        assert!(!fp.matches(&Fingerprint::synthetic(8, 64, 20.0).id()));
        // garbage ids never match
        assert!(!fp.matches(""));
        assert!(!fp.matches("c8"));
        assert!(!fp.matches("c8/l64/gx"));
        assert!(!fp.matches("c8/l64/g0/extra"));
    }

    #[test]
    fn detect_produces_plausible_figures() {
        let fp = Fingerprint::detect(40);
        assert!(fp.cores >= 1);
        assert!(fp.calib_gsps > 0.0, "calibration must measure something: {fp:?}");
        assert!(
            [16, 32, 64, 128, 256].contains(&fp.cache_line),
            "probe out of range: {}",
            fp.cache_line
        );
        assert!(fp.matches(&fp.id()));
    }
}
