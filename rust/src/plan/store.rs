//! The persistent plan store: append-only JSON lines, one [`Plan`] per
//! line, `~/.tetris/plans.jsonl` by default (`--plan-store` overrides,
//! `TETRIS_PLAN_STORE` for scripts).
//!
//! Design points:
//!
//! * **append-only writes** — tuning results land with one `O_APPEND`
//!   line, so concurrent tuners and serve dispatchers never clobber
//!   each other; on read, the *latest* record for a key wins;
//! * **tolerant reads** — unknown fields are ignored and corrupt lines
//!   are skipped with a warning (a half-written line from a crashed
//!   process must not poison every stored plan);
//! * **atomic compaction** — [`PlanStore::compact`] dedupes to the
//!   latest record per key and replaces the file via tmp + `rename`,
//!   so a reader never observes a torn store;
//! * **nearest-bucket warm start** — [`PlanStore::lookup_near`] serves
//!   the closest shape bucket for the same machine/bench/boundary when
//!   no exact key exists.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::PathBuf;

use crate::util::error::{Context, Result};

use super::fingerprint::Fingerprint;
use super::{shape_bucket, Plan};

pub struct PlanStore {
    pub path: PathBuf,
}

impl PlanStore {
    /// A store at `path` (nothing is touched until a read or write).
    pub fn open(path: impl Into<PathBuf>) -> PlanStore {
        PlanStore { path: path.into() }
    }

    /// `$TETRIS_PLAN_STORE`, else `~/.tetris/plans.jsonl` (falling back
    /// to the working directory when `HOME` is unset).
    pub fn default_path() -> PathBuf {
        if let Some(p) = std::env::var_os("TETRIS_PLAN_STORE") {
            return PathBuf::from(p);
        }
        let home = std::env::var_os("HOME")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        home.join(".tetris").join("plans.jsonl")
    }

    /// Every parseable plan, in file order (missing file = empty store).
    /// Corrupt lines are skipped with a warning; unknown fields inside
    /// valid lines are ignored by the codec.
    pub fn load(&self) -> Vec<Plan> {
        let Ok(text) = fs::read_to_string(&self.path) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match Plan::parse_line(line) {
                Ok(p) => out.push(p),
                Err(e) => eprintln!(
                    "tetris plan store: skipping corrupt line {} of {:?}: {e}",
                    i + 1,
                    self.path
                ),
            }
        }
        out
    }

    /// Latest plan for the exact `(fingerprint, bench, boundary kind,
    /// shape bucket)` key.  Plans recorded by a non-matching fingerprint
    /// (another machine) are ignored, never misapplied.
    pub fn lookup(
        &self,
        fp: &Fingerprint,
        bench: &str,
        boundary_kind: &str,
        shape: &[usize],
    ) -> Option<Plan> {
        let bucket = shape_bucket(shape);
        self.load().into_iter().rev().find(|p| {
            p.bench == bench
                && p.boundary == boundary_kind
                && p.bucket == bucket
                && fp.matches(&p.fingerprint)
        })
    }

    /// Warm start: the plan for the same machine/bench/boundary whose
    /// bucket is nearest in summed |log2| distance (later records win
    /// ties).  `None` when nothing for the triple is stored at all.
    pub fn lookup_near(
        &self,
        fp: &Fingerprint,
        bench: &str,
        boundary_kind: &str,
        shape: &[usize],
    ) -> Option<Plan> {
        let bucket = shape_bucket(shape);
        let mut best: Option<(f64, Plan)> = None;
        for p in self.load() {
            if p.bench != bench
                || p.boundary != boundary_kind
                || p.bucket.len() != bucket.len()
                || !fp.matches(&p.fingerprint)
            {
                continue;
            }
            let d: f64 = p
                .bucket
                .iter()
                .zip(&bucket)
                .map(|(&a, &b)| ((a.max(1) as f64).log2() - (b.max(1) as f64).log2()).abs())
                .sum();
            let take = match &best {
                None => true,
                Some((bd, _)) => d <= *bd,
            };
            if take {
                best = Some((d, p));
            }
        }
        best.map(|(_, p)| p)
    }

    /// Append one plan record (creates the store and its directory on
    /// first use).
    pub fn append(&self, plan: &Plan) -> Result<()> {
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating plan-store dir {dir:?}"))?;
            }
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening plan store {:?}", self.path))?;
        writeln!(f, "{}", plan.to_json())?;
        Ok(())
    }

    /// Dedupe to the latest record per key and atomically rewrite the
    /// store (tmp file + `rename`, same directory).  Returns the number
    /// of surviving plans.
    pub fn compact(&self) -> Result<usize> {
        let mut latest: BTreeMap<String, Plan> = BTreeMap::new();
        for p in self.load() {
            latest.insert(p.key(), p);
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating plan-store dir {dir:?}"))?;
            }
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating {tmp:?}"))?;
            for p in latest.values() {
                writeln!(f, "{}", p.to_json())?;
            }
            f.sync_all().ok();
        }
        fs::rename(&tmp, &self.path)
            .with_context(|| format!("replacing {:?}", self.path))?;
        Ok(latest.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PLAN_VERSION;

    fn plan(fp: &str, bench: &str, boundary: &str, bucket: Vec<usize>, engine: &str) -> Plan {
        Plan {
            version: PLAN_VERSION,
            fingerprint: fp.into(),
            bench: bench.into(),
            boundary: boundary.into(),
            bucket,
            engine: engine.into(),
            threads: 1,
            tb: 2,
            tile_w: None,
            gsps: 1.0,
            source: "tuned".into(),
            seed: 0,
        }
    }

    fn temp(tag: &str) -> PlanStore {
        let path = std::env::temp_dir()
            .join(format!("tetris-store-{tag}-{}.jsonl", std::process::id()));
        let _ = fs::remove_file(&path);
        PlanStore::open(path)
    }

    #[test]
    fn missing_store_is_empty_not_an_error() {
        let s = temp("missing");
        assert!(s.load().is_empty());
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        assert!(s.lookup(&fp, "heat2d", "dirichlet", &[64, 64]).is_none());
        assert!(s.lookup_near(&fp, "heat2d", "dirichlet", &[64, 64]).is_none());
    }

    #[test]
    fn append_lookup_latest_wins_and_compact_is_idempotent() {
        let s = temp("latest");
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        s.append(&plan(&fp.id(), "heat2d", "periodic", vec![64, 64], "simd")).unwrap();
        s.append(&plan(&fp.id(), "heat2d", "periodic", vec![64, 64], "tetris-cpu")).unwrap();
        s.append(&plan(&fp.id(), "heat2d", "periodic", vec![128, 128], "tiled")).unwrap();
        assert_eq!(s.load().len(), 3);
        assert_eq!(
            s.lookup(&fp, "heat2d", "periodic", &[60, 60]).unwrap().engine,
            "tetris-cpu",
            "the later record for a key must win"
        );
        assert_eq!(s.compact().unwrap(), 2, "duplicate key collapses");
        assert_eq!(s.load().len(), 2);
        assert_eq!(s.lookup(&fp, "heat2d", "periodic", &[60, 60]).unwrap().engine, "tetris-cpu");
        assert_eq!(s.compact().unwrap(), 2, "compacting a compact store changes nothing");
        let _ = fs::remove_file(&s.path);
    }

    #[test]
    fn corrupt_lines_are_skipped_and_the_rest_recovered() {
        let s = temp("corrupt");
        s.append(&plan("c4/l64/g0", "heat1d", "dirichlet", vec![64], "simd")).unwrap();
        {
            let mut f = fs::OpenOptions::new().append(true).open(&s.path).unwrap();
            writeln!(f, "{{\"bench\": \"heat1d\", \"bucket\": [64,").unwrap(); // torn write
            writeln!(f, "not json at all").unwrap();
        }
        s.append(&plan("c4/l64/g0", "heat1d", "dirichlet", vec![128], "tiled")).unwrap();
        let plans = s.load();
        assert_eq!(plans.len(), 2, "both valid records recovered around the corruption");
        assert_eq!(s.compact().unwrap(), 2);
        assert_eq!(s.load().len(), 2, "compaction heals the store");
        let _ = fs::remove_file(&s.path);
    }

    #[test]
    fn lookup_filters_by_fingerprint_and_near_finds_closest_bucket() {
        let s = temp("near");
        let ours = Fingerprint::synthetic(4, 64, 1.0);
        let foreign = Fingerprint::synthetic(96, 128, 300.0);
        s.append(&plan(&foreign.id(), "heat2d", "dirichlet", vec![64, 64], "naive")).unwrap();
        s.append(&plan(&ours.id(), "heat2d", "dirichlet", vec![256, 256], "tetris-cpu")).unwrap();
        s.append(&plan(&ours.id(), "heat2d", "dirichlet", vec![1024, 1024], "tiled")).unwrap();
        // exact bucket exists only under the foreign fingerprint: ignored
        assert!(s.lookup(&ours, "heat2d", "dirichlet", &[64, 64]).is_none());
        // near lookup picks our 256-bucket plan (distance 4), never the
        // foreign exact match
        let near = s.lookup_near(&ours, "heat2d", "dirichlet", &[64, 64]).unwrap();
        assert_eq!(near.engine, "tetris-cpu");
        // and from above, the 1024 plan is closer to 2048-sized shapes
        let near = s.lookup_near(&ours, "heat2d", "dirichlet", &[2000, 2000]).unwrap();
        assert_eq!(near.engine, "tiled");
        // other boundary kind / bench: nothing
        assert!(s.lookup_near(&ours, "heat2d", "periodic", &[256, 256]).is_none());
        assert!(s.lookup_near(&ours, "heat3d", "dirichlet", &[256, 256]).is_none());
        let _ = fs::remove_file(&s.path);
    }
}
