//! The persistent plan store: append-only JSON lines, one [`Plan`] per
//! line, `~/.tetris/plans.jsonl` by default (`--plan-store` overrides,
//! `TETRIS_PLAN_STORE` for scripts).
//!
//! Design points:
//!
//! * **append-only writes** — tuning results land with one `O_APPEND`
//!   line, so concurrent tuners and serve dispatchers never clobber
//!   each other; on read, the *latest* record for a key wins;
//! * **tolerant reads** — unknown fields are ignored and corrupt lines
//!   are skipped with a warning (a half-written line from a crashed
//!   process must not poison every stored plan);
//! * **atomic compaction** — [`PlanStore::compact`] dedupes to the
//!   latest record per key and replaces the file via a
//!   per-process-unique tmp + `rename`, so a reader never observes a
//!   torn store.  Writers within one process (serve dispatchers, a
//!   concurrent `tetris tune`) serialize on a per-path lock, so an
//!   append can never land between compaction's load and rename and be
//!   silently dropped; against *other* processes the compactor
//!   re-merges any records appended since its load before renaming
//!   (best-effort — the append-only format keeps even a lost record a
//!   re-tunable cache miss, never corruption);
//! * **nearest-bucket warm start** — [`PlanStore::lookup_near`] serves
//!   the closest shape bucket for the same machine/bench/boundary when
//!   no exact key exists.  [`PlanStore::lookup_in`] /
//!   [`PlanStore::lookup_near_in`] run the same probes over one loaded
//!   snapshot, so a resolution ladder reads the file once, not per probe.

use std::collections::{BTreeMap, HashMap};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::error::{Context, Result};

use super::fingerprint::Fingerprint;
use super::{shape_bucket, Plan};

/// In-process writer lock per store path: appends and compactions on
/// the same path serialize, so a compaction never races a same-process
/// append (the cross-process story is the re-merge in `compact`).
fn path_lock(path: &Path) -> Arc<Mutex<()>> {
    static LOCKS: OnceLock<Mutex<HashMap<PathBuf, Arc<Mutex<()>>>>> = OnceLock::new();
    let map = LOCKS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut g = map.lock().unwrap_or_else(|e| e.into_inner());
    g.entry(path.to_path_buf()).or_default().clone()
}

pub struct PlanStore {
    pub path: PathBuf,
}

impl PlanStore {
    /// A store at `path` (nothing is touched until a read or write).
    pub fn open(path: impl Into<PathBuf>) -> PlanStore {
        PlanStore { path: path.into() }
    }

    /// `$TETRIS_PLAN_STORE`, else `~/.tetris/plans.jsonl` (falling back
    /// to the working directory when `HOME` is unset).
    pub fn default_path() -> PathBuf {
        if let Some(p) = std::env::var_os("TETRIS_PLAN_STORE") {
            return PathBuf::from(p);
        }
        let home = std::env::var_os("HOME")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        home.join(".tetris").join("plans.jsonl")
    }

    /// Every parseable plan, in file order (missing file = empty store).
    /// Corrupt lines are skipped with a warning; unknown fields inside
    /// valid lines are ignored by the codec.
    pub fn load(&self) -> Vec<Plan> {
        let Ok(text) = fs::read_to_string(&self.path) else {
            return Vec::new();
        };
        Self::parse_lines(&text, &self.path)
    }

    fn parse_lines(text: &str, path: &Path) -> Vec<Plan> {
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match Plan::parse_line(line) {
                Ok(p) => out.push(p),
                Err(e) => eprintln!(
                    "tetris plan store: skipping corrupt line {} of {path:?}: {e}",
                    i + 1,
                ),
            }
        }
        out
    }

    /// Latest plan for the exact `(fingerprint, bench, boundary kind,
    /// shape bucket)` key.  Plans recorded by a non-matching fingerprint
    /// (another machine) are ignored, never misapplied.
    pub fn lookup(
        &self,
        fp: &Fingerprint,
        bench: &str,
        boundary_kind: &str,
        shape: &[usize],
    ) -> Option<Plan> {
        Self::lookup_in(&self.load(), fp, bench, boundary_kind, shape)
    }

    /// [`PlanStore::lookup`] over an already-loaded snapshot, so a
    /// resolution ladder probing several ways reads the file once.
    pub fn lookup_in(
        plans: &[Plan],
        fp: &Fingerprint,
        bench: &str,
        boundary_kind: &str,
        shape: &[usize],
    ) -> Option<Plan> {
        let bucket = shape_bucket(shape);
        plans
            .iter()
            .rev()
            .find(|p| {
                p.bench == bench
                    && p.boundary == boundary_kind
                    && p.bucket == bucket
                    && fp.matches(&p.fingerprint)
            })
            .cloned()
    }

    /// Warm start: the plan for the same machine/bench/boundary whose
    /// bucket is nearest in summed |log2| distance (later records win
    /// ties).  `None` when nothing for the triple is stored at all.
    pub fn lookup_near(
        &self,
        fp: &Fingerprint,
        bench: &str,
        boundary_kind: &str,
        shape: &[usize],
    ) -> Option<Plan> {
        Self::lookup_near_in(&self.load(), fp, bench, boundary_kind, shape)
    }

    /// [`PlanStore::lookup_near`] over an already-loaded snapshot.
    pub fn lookup_near_in(
        plans: &[Plan],
        fp: &Fingerprint,
        bench: &str,
        boundary_kind: &str,
        shape: &[usize],
    ) -> Option<Plan> {
        let bucket = shape_bucket(shape);
        let mut best: Option<(f64, &Plan)> = None;
        for p in plans {
            if p.bench != bench
                || p.boundary != boundary_kind
                || p.bucket.len() != bucket.len()
                || !fp.matches(&p.fingerprint)
            {
                continue;
            }
            let d: f64 = p
                .bucket
                .iter()
                .zip(&bucket)
                .map(|(&a, &b)| ((a.max(1) as f64).log2() - (b.max(1) as f64).log2()).abs())
                .sum();
            let take = match &best {
                None => true,
                Some((bd, _)) => d <= *bd,
            };
            if take {
                best = Some((d, p));
            }
        }
        best.map(|(_, p)| p.clone())
    }

    /// Append one plan record (creates the store and its directory on
    /// first use).  Serialized against same-process compactions via the
    /// per-path lock, so a record can never land in the window between a
    /// compaction's load and its rename.
    pub fn append(&self, plan: &Plan) -> Result<()> {
        let lock = path_lock(&self.path);
        let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating plan-store dir {dir:?}"))?;
            }
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening plan store {:?}", self.path))?;
        writeln!(f, "{}", plan.to_json())?;
        Ok(())
    }

    /// Dedupe to the latest record per key and atomically rewrite the
    /// store (per-process-unique tmp file + `rename`, same directory —
    /// the tmp name *appends* a suffix, so custom `--plan-store` paths
    /// with their own extensions are preserved, and two concurrent
    /// compactions never interleave writes into one tmp).  Same-process
    /// appends are excluded by the per-path lock; records appended by
    /// *other* processes between the load and the rename are re-merged
    /// before renaming (re-checked a few times, best-effort).  Returns
    /// the number of surviving plans.
    pub fn compact(&self) -> Result<usize> {
        let lock = path_lock(&self.path);
        let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
        let text = fs::read_to_string(&self.path).unwrap_or_default();
        let mut latest: BTreeMap<String, Plan> = BTreeMap::new();
        for p in Self::parse_lines(&text, &self.path) {
            latest.insert(p.key(), p);
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating plan-store dir {dir:?}"))?;
            }
        }
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = PathBuf::from(format!(
            "{}.compact.{}.{}.tmp",
            self.path.display(),
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        let write_tmp = |latest: &BTreeMap<String, Plan>| -> Result<()> {
            let mut f =
                fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
            for p in latest.values() {
                writeln!(f, "{}", p.to_json())?;
            }
            f.sync_all().ok();
            Ok(())
        };
        write_tmp(&latest)?;
        // Cross-process re-merge: fold in anything appended after our
        // load.  A shrink means another compactor already renamed — its
        // result is as good as ours, so stop re-reading and let the
        // last rename win.
        let mut seen = text.len();
        for _ in 0..4 {
            let now = fs::read_to_string(&self.path).unwrap_or_default();
            if now.len() <= seen {
                break;
            }
            let Some(tail) = now.get(seen..) else { break };
            let appended = Self::parse_lines(tail, &self.path);
            if !appended.is_empty() {
                for p in appended {
                    latest.insert(p.key(), p);
                }
                write_tmp(&latest)?;
            }
            seen = now.len();
        }
        if let Err(e) = fs::rename(&tmp, &self.path) {
            let _ = fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("replacing {:?}", self.path));
        }
        Ok(latest.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::PLAN_VERSION;

    fn plan(fp: &str, bench: &str, boundary: &str, bucket: Vec<usize>, engine: &str) -> Plan {
        Plan {
            version: PLAN_VERSION,
            fingerprint: fp.into(),
            bench: bench.into(),
            boundary: boundary.into(),
            bucket,
            engine: engine.into(),
            threads: 1,
            tb: 2,
            tile_w: None,
            overlap: None,
            grid: None,
            gsps: 1.0,
            source: "tuned".into(),
            seed: 0,
        }
    }

    fn temp(tag: &str) -> PlanStore {
        let path = std::env::temp_dir()
            .join(format!("tetris-store-{tag}-{}.jsonl", std::process::id()));
        let _ = fs::remove_file(&path);
        PlanStore::open(path)
    }

    #[test]
    fn missing_store_is_empty_not_an_error() {
        let s = temp("missing");
        assert!(s.load().is_empty());
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        assert!(s.lookup(&fp, "heat2d", "dirichlet", &[64, 64]).is_none());
        assert!(s.lookup_near(&fp, "heat2d", "dirichlet", &[64, 64]).is_none());
    }

    #[test]
    fn append_lookup_latest_wins_and_compact_is_idempotent() {
        let s = temp("latest");
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        s.append(&plan(&fp.id(), "heat2d", "periodic", vec![64, 64], "simd")).unwrap();
        s.append(&plan(&fp.id(), "heat2d", "periodic", vec![64, 64], "tetris-cpu")).unwrap();
        s.append(&plan(&fp.id(), "heat2d", "periodic", vec![128, 128], "tiled")).unwrap();
        assert_eq!(s.load().len(), 3);
        assert_eq!(
            s.lookup(&fp, "heat2d", "periodic", &[60, 60]).unwrap().engine,
            "tetris-cpu",
            "the later record for a key must win"
        );
        assert_eq!(s.compact().unwrap(), 2, "duplicate key collapses");
        assert_eq!(s.load().len(), 2);
        assert_eq!(s.lookup(&fp, "heat2d", "periodic", &[60, 60]).unwrap().engine, "tetris-cpu");
        assert_eq!(s.compact().unwrap(), 2, "compacting a compact store changes nothing");
        let _ = fs::remove_file(&s.path);
    }

    #[test]
    fn corrupt_lines_are_skipped_and_the_rest_recovered() {
        let s = temp("corrupt");
        s.append(&plan("c4/l64/g0", "heat1d", "dirichlet", vec![64], "simd")).unwrap();
        {
            let mut f = fs::OpenOptions::new().append(true).open(&s.path).unwrap();
            writeln!(f, "{{\"bench\": \"heat1d\", \"bucket\": [64,").unwrap(); // torn write
            writeln!(f, "not json at all").unwrap();
        }
        s.append(&plan("c4/l64/g0", "heat1d", "dirichlet", vec![128], "tiled")).unwrap();
        let plans = s.load();
        assert_eq!(plans.len(), 2, "both valid records recovered around the corruption");
        assert_eq!(s.compact().unwrap(), 2);
        assert_eq!(s.load().len(), 2, "compaction heals the store");
        let _ = fs::remove_file(&s.path);
    }

    /// Regression (compact-vs-append): a compaction running concurrently
    /// with a stream of appends must not drop any appended record — the
    /// old code loaded, rewrote a shared tmp and renamed over the store,
    /// silently losing anything appended between load and rename.
    #[test]
    fn concurrent_appends_survive_compaction() {
        let s = temp("compact-race");
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        s.append(&plan(&fp.id(), "heat2d", "dirichlet", vec![8, 8], "simd")).unwrap();
        let path = s.path.clone();
        let fpid = fp.id();
        let appender = std::thread::spawn(move || {
            let store = PlanStore::open(&path);
            for i in 0..40usize {
                // distinct bucket per record = distinct key, so every
                // append must survive every concurrent compaction
                let b = 1usize << (i % 20);
                store
                    .append(&plan(&fpid, "heat2d", "dirichlet", vec![b, i + 1], "simd"))
                    .unwrap();
                if i % 8 == 0 {
                    std::thread::yield_now();
                }
            }
        });
        let path = s.path.clone();
        let compactor = std::thread::spawn(move || {
            let store = PlanStore::open(&path);
            for _ in 0..10 {
                store.compact().unwrap();
                std::thread::yield_now();
            }
        });
        appender.join().unwrap();
        compactor.join().unwrap();
        assert_eq!(s.compact().unwrap(), 41, "no appended record may be dropped");
        assert_eq!(s.load().len(), 41);
        let _ = fs::remove_file(&s.path);
    }

    /// Regression: the compaction tmp name must *append* a suffix — the
    /// old `with_extension("jsonl.tmp")` mangled custom `--plan-store`
    /// paths carrying their own extension (`my.plans` -> `my.jsonl.tmp`),
    /// so two stores named `a.plans`/`a.conf` would share one tmp.
    #[test]
    fn compact_preserves_custom_extension_paths() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("tetris-store-ext-{}.plans", std::process::id()));
        let mangled = dir.join(format!("tetris-store-ext-{}.jsonl.tmp", std::process::id()));
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(&mangled);
        let s = PlanStore::open(&path);
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        s.append(&plan(&fp.id(), "heat1d", "dirichlet", vec![64], "simd")).unwrap();
        assert_eq!(s.compact().unwrap(), 1);
        assert!(path.exists(), "store must survive compaction at its own path");
        assert!(!mangled.exists(), "with_extension-style tmp must not appear");
        assert_eq!(s.load().len(), 1);
        let _ = fs::remove_file(&path);
    }

    /// Snapshot probes (`lookup_in`/`lookup_near_in`) serve the same
    /// answers as the file-backed probes from ONE load — the single-read
    /// contract `resolve_auto` relies on.
    #[test]
    fn snapshot_probes_match_file_probes_without_rereading() {
        let s = temp("snapshot");
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        s.append(&plan(&fp.id(), "heat2d", "dirichlet", vec![64, 64], "simd")).unwrap();
        s.append(&plan(&fp.id(), "heat2d", "dirichlet", vec![256, 256], "tiled")).unwrap();
        let exact_file = s.lookup(&fp, "heat2d", "dirichlet", &[64, 64]);
        let near_file = s.lookup_near(&fp, "heat2d", "dirichlet", &[100, 100]);
        let snapshot = s.load();
        // deleting the file proves the snapshot probes never re-read it
        fs::remove_file(&s.path).unwrap();
        assert_eq!(
            PlanStore::lookup_in(&snapshot, &fp, "heat2d", "dirichlet", &[64, 64]),
            exact_file
        );
        assert_eq!(
            PlanStore::lookup_near_in(&snapshot, &fp, "heat2d", "dirichlet", &[100, 100]),
            near_file
        );
        assert!(s.lookup(&fp, "heat2d", "dirichlet", &[64, 64]).is_none());
    }

    #[test]
    fn lookup_filters_by_fingerprint_and_near_finds_closest_bucket() {
        let s = temp("near");
        let ours = Fingerprint::synthetic(4, 64, 1.0);
        let foreign = Fingerprint::synthetic(96, 128, 300.0);
        s.append(&plan(&foreign.id(), "heat2d", "dirichlet", vec![64, 64], "naive")).unwrap();
        s.append(&plan(&ours.id(), "heat2d", "dirichlet", vec![256, 256], "tetris-cpu")).unwrap();
        s.append(&plan(&ours.id(), "heat2d", "dirichlet", vec![1024, 1024], "tiled")).unwrap();
        // exact bucket exists only under the foreign fingerprint: ignored
        assert!(s.lookup(&ours, "heat2d", "dirichlet", &[64, 64]).is_none());
        // near lookup picks our 256-bucket plan (distance 4), never the
        // foreign exact match
        let near = s.lookup_near(&ours, "heat2d", "dirichlet", &[64, 64]).unwrap();
        assert_eq!(near.engine, "tetris-cpu");
        // and from above, the 1024 plan is closer to 2048-sized shapes
        let near = s.lookup_near(&ours, "heat2d", "dirichlet", &[2000, 2000]).unwrap();
        assert_eq!(near.engine, "tiled");
        // other boundary kind / bench: nothing
        assert!(s.lookup_near(&ours, "heat2d", "periodic", &[256, 256]).is_none());
        assert!(s.lookup_near(&ours, "heat3d", "dirichlet", &[256, 256]).is_none());
        let _ = fs::remove_file(&s.path);
    }
}
