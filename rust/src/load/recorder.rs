//! Per-rung measurement: counts every job offered and files every reply
//! into the same [`LatencyHistogram`] type the server reports from.
//!
//! Three latency views per job:
//! * **queue** — the server-reported admission→dispatch wait (`queue_ms`);
//! * **service** — the server-reported execution time (`exec_ms`);
//! * **total** — the client-measured send→reply round trip, which is the
//!   only one that includes socket and reply-ordering delay.
//!
//! Rejects are *not* latency samples — they are counted separately and
//! their `retry_after_ms` hints collected verbatim, because the hint
//! distribution is itself an output of the experiment (it is the
//! backpressure signal a well-behaved client would obey).
//!
//! With `--retry N` the clients *do* obey it: a retryable reject is
//! recorded via [`Recorder::on_retry`] (not a terminal outcome), and
//! only the final reply settles the job — so `offered` keeps counting
//! unique jobs and the conservation invariant
//! `offered == completed + rejected + errors + lost` survives retries,
//! extended by `gave_up <= rejected` and `gave_up <= retried`.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::serve::{JobResult, LatencyHistogram};
use crate::util::json::Json;

#[derive(Debug, Default, Clone)]
pub struct Recorder {
    /// Jobs put on the wire.
    pub offered: u64,
    /// `ok:true` replies.
    pub completed: u64,
    /// Admission rejects (`ok:false` with a `retry_after_ms` hint).
    pub rejected: u64,
    /// Other failures (parse/run errors — `ok:false`, no hint).
    pub errors: u64,
    /// Offered jobs that never got any reply (connection died).
    pub lost: u64,
    /// Resubmissions after a retryable reject (`--retry`); NOT new
    /// offered jobs — `offered` counts unique jobs only.
    pub retried: u64,
    /// Retried jobs whose final reply was still a reject (a subset of
    /// `rejected`: the retry budget ran out).
    pub gave_up: u64,
    pub queue: LatencyHistogram,
    pub service: LatencyHistogram,
    pub total: LatencyHistogram,
    /// Observed backpressure hints, one per reject, in arrival order.
    pub retry_hints_ms: Vec<u64>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// A job went on the wire.
    pub fn on_send(&mut self) {
        self.offered += 1;
    }

    /// Its (in-order) reply came back `round_trip` after the send.
    pub fn on_reply(&mut self, r: &JobResult, round_trip: Duration) {
        if r.ok {
            self.completed += 1;
            self.queue.record_ms(r.queue_ms);
            self.service.record_ms(r.exec_ms);
            self.total.record(round_trip);
        } else if let Some(hint) = r.retry_after_ms {
            self.rejected += 1;
            self.retry_hints_ms.push(hint);
        } else {
            self.errors += 1;
        }
    }

    /// An offered job whose reply will never arrive.
    pub fn on_lost(&mut self) {
        self.lost += 1;
    }

    /// A retryable reject the client will obey: record the hint and the
    /// resubmission; terminal accounting waits for the final reply.
    pub fn on_retry(&mut self, hint_ms: u64) {
        self.retried += 1;
        self.retry_hints_ms.push(hint_ms);
    }

    /// A retried job's final reply was still a reject — call *after*
    /// the terminal [`Recorder::on_reply`].
    pub fn on_gave_up(&mut self) {
        self.gave_up += 1;
    }

    /// Fold a per-connection recorder into the rung total.
    pub fn merge(&mut self, other: &Recorder) {
        self.offered += other.offered;
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.errors += other.errors;
        self.lost += other.lost;
        self.retried += other.retried;
        self.gave_up += other.gave_up;
        self.queue.merge(&other.queue);
        self.service.merge(&other.service);
        self.total.merge(&other.total);
        self.retry_hints_ms.extend_from_slice(&other.retry_hints_ms);
    }

    /// Every offered job must be accounted for exactly once; retries
    /// are resubmissions of already-offered jobs, so they extend rather
    /// than weaken the balance: giving up implies a terminal reject and
    /// at least one earlier resubmission.
    pub fn conserved(&self) -> bool {
        self.offered == self.completed + self.rejected + self.errors + self.lost
            && self.gave_up <= self.rejected
            && self.gave_up <= self.retried
    }

    /// Summary of the observed `retry_after_ms` hints: count, how many
    /// were the hard `0` (= do not retry), min/p50/max/mean.
    pub fn retry_hint_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let mut sorted = self.retry_hints_ms.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        m.insert("count".into(), Json::Num(n as f64));
        m.insert(
            "zeros".into(),
            Json::Num(sorted.iter().take_while(|&&h| h == 0).count() as f64),
        );
        m.insert("min_ms".into(), Json::Num(sorted.first().copied().unwrap_or(0) as f64));
        m.insert("p50_ms".into(), Json::Num(if n == 0 { 0.0 } else { sorted[(n - 1) / 2] as f64 }));
        m.insert("max_ms".into(), Json::Num(sorted.last().copied().unwrap_or(0) as f64));
        let mean = if n == 0 { 0.0 } else { sorted.iter().sum::<u64>() as f64 / n as f64 };
        m.insert("mean_ms".into(), Json::Num(mean));
        Json::Obj(m)
    }

    /// The rung's latency block: one histogram JSON per view.
    pub fn latency_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("queue".into(), self.queue.to_json());
        m.insert("service".into(), self.service.to_json());
        m.insert("total".into(), self.total.to_json());
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::JobResult;

    fn ok_reply(queue_ms: f64, exec_ms: f64) -> JobResult {
        JobResult { ok: true, queue_ms, exec_ms, ..Default::default() }
    }

    #[test]
    fn replies_land_in_the_right_counters() {
        let mut rec = Recorder::new();
        for _ in 0..3 {
            rec.on_send();
        }
        rec.on_reply(&ok_reply(1.0, 2.0), Duration::from_millis(4));
        rec.on_reply(&JobResult::reject("j", "full", 125), Duration::from_millis(1));
        rec.on_reply(&JobResult::failure("j", "bad bench"), Duration::from_millis(1));
        assert_eq!((rec.offered, rec.completed, rec.rejected, rec.errors), (3, 1, 1, 1));
        assert!(rec.conserved());
        assert_eq!(rec.retry_hints_ms, vec![125]);
        assert_eq!(rec.total.count(), 1, "only completions are latency samples");
    }

    #[test]
    fn lost_jobs_balance_conservation() {
        let mut rec = Recorder::new();
        rec.on_send();
        rec.on_send();
        rec.on_reply(&ok_reply(0.5, 1.5), Duration::from_millis(2));
        assert!(!rec.conserved(), "one reply outstanding");
        rec.on_lost();
        assert!(rec.conserved());
        assert_eq!(rec.lost, 1);
    }

    #[test]
    fn merge_sums_counters_and_histograms() {
        let mut a = Recorder::new();
        let mut b = Recorder::new();
        a.on_send();
        a.on_reply(&ok_reply(1.0, 1.0), Duration::from_millis(2));
        b.on_send();
        b.on_reply(&JobResult::reject("j", "full", 50), Duration::from_millis(1));
        a.merge(&b);
        assert_eq!((a.offered, a.completed, a.rejected), (2, 1, 1));
        assert_eq!(a.total.count(), 1);
        assert_eq!(a.retry_hints_ms, vec![50]);
        assert!(a.conserved());
    }

    #[test]
    fn retries_keep_conservation_over_unique_jobs() {
        let mut rec = Recorder::new();
        // Job 1: rejected once, retried, then completes.
        rec.on_send();
        rec.on_retry(50);
        rec.on_reply(&ok_reply(1.0, 2.0), Duration::from_millis(60));
        // Job 2: rejected, retried twice, budget exhausted — terminal reject.
        rec.on_send();
        rec.on_retry(100);
        rec.on_retry(100);
        rec.on_reply(&JobResult::reject("j2", "full", 100), Duration::from_millis(5));
        rec.on_gave_up();
        assert_eq!((rec.offered, rec.completed, rec.rejected), (2, 1, 1));
        assert_eq!((rec.retried, rec.gave_up), (3, 1));
        assert!(rec.conserved());
        // Hints from obeyed retries and the terminal reject all land.
        assert_eq!(rec.retry_hints_ms, vec![50, 100, 100, 100]);

        let mut total = Recorder::new();
        total.merge(&rec);
        assert_eq!((total.retried, total.gave_up), (3, 1));
        assert!(total.conserved());
    }

    #[test]
    fn retry_hint_summary_counts_hard_zeros() {
        let mut rec = Recorder::new();
        for hint in [0u64, 50, 0, 200, 100] {
            rec.on_send();
            rec.on_reply(&JobResult::reject("j", "full", hint), Duration::from_millis(1));
        }
        let j = rec.retry_hint_json();
        assert_eq!(j.at(&["count"]).as_usize(), Some(5));
        assert_eq!(j.at(&["zeros"]).as_usize(), Some(2));
        assert_eq!(j.at(&["min_ms"]).as_f64(), Some(0.0));
        assert_eq!(j.at(&["max_ms"]).as_f64(), Some(200.0));
        assert_eq!(j.at(&["p50_ms"]).as_f64(), Some(50.0));
        assert_eq!(j.at(&["mean_ms"]).as_f64(), Some(70.0));
    }

    #[test]
    fn latency_json_has_all_three_views_with_p999() {
        let mut rec = Recorder::new();
        rec.on_send();
        rec.on_reply(&ok_reply(1.0, 3.0), Duration::from_millis(5));
        let j = rec.latency_json();
        for view in ["queue", "service", "total"] {
            assert!(j.at(&[view, "p999_ms"]).as_f64().unwrap() > 0.0, "{view}");
        }
    }
}
