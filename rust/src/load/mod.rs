//! `tetris load` — stochastic load harness for the serving layer.
//!
//! The ROADMAP north star is "heavy traffic from millions of users";
//! every serve bench before this measured a fixed-rate loopback mean.
//! This module makes the serving claims falsifiable the way the WIND
//! bench harness does it: drive the *release server binary* as a
//! separate OS process over real TCP, and report tails, rejects and
//! resource use — not means.
//!
//! Two suites:
//! * **Suite A** (deterministic, closed loop): N connection threads,
//!   each submitting a fixed, seeded job list synchronously.  With
//!   `conns` ≤ the admission capacity this must produce **zero**
//!   rejects and zero lost replies — the byte-stable baseline (modulo
//!   timings) that `bench check` gates on.
//! * **Suite B** (stochastic, open loop): one pipelined connection;
//!   a seeded Poisson schedule paces sends regardless of server state,
//!   a zipfian-weighted mix picks each job, and an optional rate sweep
//!   multiplies the arrival rate rung by rung until sustained admission
//!   rejects — the saturation walk that locates the service's knee.
//!
//! With `--retry N` both suites *obey* the server's `retry_after_ms`
//! backpressure hint: a retryable reject is resubmitted after a capped,
//! jittered backoff (see [`backoff_delay`]) up to N times per job, and
//! the recorder counts resubmissions (`retried`) and exhausted budgets
//! (`gave_up`) without breaking the offered-jobs conservation check.
//!
//! Submodules: [`workload`] (job kinds + seeded mixes), [`arrival`]
//! (Poisson schedules), [`recorder`] (per-rung counts + the shared
//! [`crate::serve::LatencyHistogram`] views), [`resources`]
//! (`/proc/<pid>` RSS/CPU polling), [`report`] (the
//! `BENCH_serve_suite*.json` codec).

pub mod arrival;
pub mod recorder;
pub mod report;
pub mod resources;
pub mod workload;

pub use arrival::Poisson;
pub use recorder::Recorder;
pub use report::{Rung, SuiteReport};
pub use resources::{ProcMonitor, ProcSummary};
pub use workload::{standard_catalog, zipf_weights, JobKind, JobMix};

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::serve::{Client, JobSpec};
use crate::util::error::{Context, Result};
use crate::util::prng::SplitMix64;

/// Everything a load run needs; built by the CLI, consumed by the suite
/// runners and the server spawner.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Drive an already-running server instead of spawning one.
    pub addr: Option<String>,
    /// Server binary to spawn (default: the currently running binary).
    pub bin: Option<String>,
    /// `--scale` handed to the spawned server (problem-size default).
    pub scale: f64,
    /// `--threads` per dispatcher on the spawned server.
    pub threads: usize,
    /// Dispatcher count (`serve --workers`) on the spawned server.
    pub dispatchers: usize,
    /// Admission queue depth (`serve --queue`) on the spawned server.
    pub queue_jobs: usize,
    /// Master seed: pins job mixes, arrival schedules and input fields.
    pub seed: u64,
    /// Suite A: concurrent closed-loop connections.
    pub conns: usize,
    /// Suite A: jobs submitted per connection.
    pub jobs_per_conn: usize,
    /// Suite B: arrival rate (jobs/sec) of the first rung.
    pub rate: f64,
    /// Suite B: wall-clock horizon of each rung's schedule.
    pub duration: Duration,
    /// Suite B: zipf exponent of the job mix (0 = uniform).
    pub zipf_s: f64,
    /// Suite B: keep multiplying the rate until sustained rejects.
    pub sweep: bool,
    /// Rate multiplier between sweep rungs.
    pub sweep_factor: f64,
    /// Sweep safety cap on rung count.
    pub max_rungs: usize,
    /// Sweep stops once a rung's reject fraction reaches this.
    pub stop_reject_frac: f64,
    /// Max resubmissions per job after a retryable reject (0 = the
    /// pre-retry behavior: every reject is terminal).
    pub retry: usize,
    /// `FILE[:SECS]` passed through to the spawned server's
    /// `--metrics-scrape` flag (periodic JSONL metrics snapshots).
    pub metrics_scrape: Option<String>,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            addr: None,
            bin: None,
            scale: 0.05,
            threads: 1,
            dispatchers: 2,
            queue_jobs: 64,
            seed: 0x10AD,
            conns: 4,
            jobs_per_conn: 16,
            rate: 50.0,
            duration: Duration::from_secs(5),
            zipf_s: 1.1,
            sweep: false,
            sweep_factor: 2.0,
            max_rungs: 6,
            stop_reject_frac: 0.5,
            retry: 0,
            metrics_scrape: None,
        }
    }
}

/// Jittered, capped backoff for an obeyed `retry_after_ms` hint: the
/// hint (capped at 2s) scaled by a uniform factor in `[0.5, 1.5)` so
/// retrying clients don't re-arrive at the server in lockstep.
pub fn backoff_delay(hint_ms: u64, rng: &mut SplitMix64) -> Duration {
    Duration::from_millis(((hint_ms.min(2_000) as f64) * (0.5 + rng.next_f64())) as u64)
}

/// A `tetris serve` child process the harness booted and owns.  Dropping
/// it without [`SpawnedServer::shutdown`] kills the child, so a failing
/// suite never leaks a listener.
pub struct SpawnedServer {
    child: std::process::Child,
    pub addr: String,
    done: bool,
}

impl SpawnedServer {
    pub fn pid(&self) -> u32 {
        self.child.id()
    }

    /// Graceful drain: `SHUTDOWN` over the protocol, then reap.
    pub fn shutdown(&mut self) -> Result<()> {
        let mut c = Client::connect(self.addr.as_str())?;
        c.shutdown()?;
        self.child.wait()?;
        self.done = true;
        Ok(())
    }
}

impl Drop for SpawnedServer {
    fn drop(&mut self) {
        if !self.done {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
    }
}

/// Boot the release server as a separate OS process on an ephemeral
/// loopback port (`--addr-file` handshake, `--plan-store none` so load
/// runs never pollute the user's plan store) and wait for its address.
pub fn spawn_server(cfg: &LoadConfig) -> Result<SpawnedServer> {
    let bin = match &cfg.bin {
        Some(b) => PathBuf::from(b),
        None => std::env::current_exe().context("locating the tetris binary")?,
    };
    let addr_file = std::env::temp_dir().join(format!(
        "tetris-load-addr-{}-{:x}.txt",
        std::process::id(),
        cfg.seed
    ));
    let _ = std::fs::remove_file(&addr_file);
    let mut cmd = Command::new(&bin);
    cmd.arg("serve")
        .args(["--addr", "127.0.0.1:0"])
        .arg("--addr-file")
        .arg(&addr_file)
        .args(["--plan-store", "none"])
        .args(["--workers", &cfg.dispatchers.to_string()])
        .args(["--queue", &cfg.queue_jobs.to_string()])
        .args(["--threads", &cfg.threads.to_string()])
        .args(["--scale", &cfg.scale.to_string()]);
    if let Some(scrape) = &cfg.metrics_scrape {
        cmd.args(["--metrics-scrape", scrape]);
    }
    let child = cmd
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| format!("spawning {} serve", bin.display()))?;
    let mut server = SpawnedServer { child, addr: String::new(), done: false };
    for _ in 0..200 {
        if let Ok(s) = std::fs::read_to_string(&addr_file) {
            let s = s.trim();
            if !s.is_empty() {
                server.addr = s.to_string();
                break;
            }
        }
        if let Some(status) = server.child.try_wait()? {
            crate::bail!("spawned server exited before publishing its address ({status})");
        }
        thread::sleep(Duration::from_millis(50));
    }
    let _ = std::fs::remove_file(&addr_file);
    crate::ensure!(
        !server.addr.is_empty(),
        "spawned server never published its address (waited 10s)"
    );
    Ok(server)
}

/// Suite A: deterministic closed-loop baseline.  `conns` threads each
/// submit their seeded `jobs_per_conn` list synchronously; with the
/// connection count at or below the admission capacity this yields zero
/// rejects, so any nonzero reject/lost count is a server bug, not load.
pub fn run_suite_a(addr: &str, cfg: &LoadConfig) -> Result<SuiteReport> {
    let mix = JobMix::standard_uniform();
    let conns = cfg.conns.max(1);
    let jobs = cfg.jobs_per_conn.max(1);
    let t0 = Instant::now();
    let per_conn: Vec<Result<Recorder>> = thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                let mix = &mix;
                s.spawn(move || -> Result<Recorder> {
                    let mut rng = SplitMix64::new(cfg.seed ^ (0xA150_0000 + c as u64));
                    let mut client = Client::connect(addr)?;
                    let mut rec = Recorder::new();
                    'jobs: for j in 0..jobs {
                        let kind = mix.sample(&mut rng);
                        let spec =
                            mix.spec(kind, format!("a{c}-{j}"), cfg.seed + (c * jobs + j) as u64);
                        let sent_at = Instant::now();
                        rec.on_send();
                        let mut attempts = 0usize;
                        loop {
                            match client.submit(&spec) {
                                Ok(reply) => {
                                    let hint =
                                        if reply.ok { 0 } else { reply.retry_after_ms.unwrap_or(0) };
                                    if hint > 0 && attempts < cfg.retry {
                                        attempts += 1;
                                        rec.on_retry(hint);
                                        thread::sleep(backoff_delay(hint, &mut rng));
                                        continue;
                                    }
                                    // Round trip includes the backoff the client
                                    // chose to take — that's the latency it saw.
                                    rec.on_reply(&reply, sent_at.elapsed());
                                    if attempts > 0 && !reply.ok && reply.retry_after_ms.is_some() {
                                        rec.on_gave_up();
                                    }
                                    break;
                                }
                                Err(_) => {
                                    rec.on_lost();
                                    break 'jobs;
                                }
                            }
                        }
                    }
                    Ok(rec)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(crate::err!("suite A connection thread panicked")))
            })
            .collect()
    });
    let wall = t0.elapsed();
    let mut rec = Recorder::new();
    for r in per_conn {
        rec.merge(&r?);
    }
    let rung = Rung {
        label: format!("conns={conns}"),
        offered_rate: 0.0,
        rec,
        wall,
        metrics: snapshot_metrics(addr),
    };
    Ok(SuiteReport { name: "suiteA".into(), seed: cfg.seed, rungs: vec![rung] })
}

/// One Suite B rung: a seeded Poisson schedule at `rate` jobs/sec over
/// `cfg.duration`, sent open-loop down one pipelined connection.  The
/// sender thread paces arrivals and hands each `(job idx, first send
/// instant, send failed)` to the receiver through a channel; the
/// server's per-connection reply ordering pairs those with replies in
/// order.  Retries flow the other way: the receiver schedules obeyed
/// `retry_after_ms` hints on a second channel and the sender resubmits
/// them once the schedule is drained (or between paced arrivals' ends).
/// The `inflight` counter is the shutdown handshake: the sender only
/// quits on "retry queue empty AND nothing in flight", and because the
/// receiver enqueues a retry *before* decrementing `inflight`, the
/// sender re-checks the retry queue once after seeing zero — a retry
/// can never fall into the gap.
fn run_rung_b(addr: &str, cfg: &LoadConfig, rate: f64, rung_idx: usize) -> Result<Rung> {
    let mix = JobMix::standard_zipf(cfg.zipf_s);
    let seed = cfg.seed.wrapping_add(0x9E37_79B9u64.wrapping_mul(rung_idx as u64 + 1));
    let offsets = Poisson::new(rate, seed).schedule(cfg.duration);
    crate::ensure!(
        !offsets.is_empty(),
        "rate {rate}/s over {:?} produced no arrivals; raise --rate or --duration",
        cfg.duration
    );
    let mut rng = SplitMix64::new(seed ^ 0xB);
    let specs: Vec<JobSpec> = (0..offsets.len())
        .map(|i| {
            let kind = mix.sample(&mut rng);
            mix.spec(kind, format!("b{rung_idx}-{i}"), seed.wrapping_add(i as u64))
        })
        .collect();
    let (mut send, mut recv) = Client::connect(addr)?.split();
    // Sent jobs: (job idx, instant of the job's FIRST send, send failed).
    let (tx, rx) = mpsc::channel::<(usize, Instant, bool)>();
    // Scheduled retries: (job idx, earliest resend instant, first send).
    let (retry_tx, retry_rx) = mpsc::channel::<(usize, Instant, Instant)>();
    let inflight = AtomicUsize::new(0);
    let t0 = Instant::now();
    let mut rec = Recorder::new();
    thread::scope(|s| {
        let (offsets, specs, inflight) = (&offsets, &specs, &inflight);
        s.spawn(move || {
            let start = Instant::now();
            for (i, (off, spec)) in offsets.iter().zip(specs).enumerate() {
                let now = start.elapsed();
                if *off > now {
                    thread::sleep(*off - now);
                }
                // Count the job in flight BEFORE the send: every
                // fetch_add is matched by exactly one tx item, and only
                // the receiver ever decrements (once per rx item).
                inflight.fetch_add(1, Ordering::SeqCst);
                let failed = send.send_spec(spec).is_err();
                if tx.send((i, Instant::now(), failed)).is_err() {
                    return;
                }
                if failed {
                    break; // connection dead; skip to the drain below
                }
            }
            // Drain scheduled retries until none remain and none can
            // still appear (the receiver has nothing left in flight).
            loop {
                let item = match retry_rx.try_recv() {
                    Ok(it) => Some(it),
                    Err(mpsc::TryRecvError::Disconnected) => None,
                    Err(mpsc::TryRecvError::Empty) => {
                        if inflight.load(Ordering::SeqCst) > 0 {
                            thread::sleep(Duration::from_millis(1));
                            continue;
                        }
                        // A retry is enqueued before its reply's
                        // decrement, so after seeing zero one more
                        // look settles whether a retry raced in.
                        retry_rx.try_recv().ok()
                    }
                };
                let Some((i, not_before, first_sent)) = item else { break };
                let now = Instant::now();
                if not_before > now {
                    thread::sleep(not_before - now);
                }
                inflight.fetch_add(1, Ordering::SeqCst);
                let failed = send.send_spec(&specs[i]).is_err();
                if tx.send((i, first_sent, failed)).is_err() {
                    return;
                }
                if failed {
                    break; // receiver loses the rest via pending_retry
                }
            }
            // tx drops here: the receiver's channel drains and closes
        });
        // Receiver runs inline: replies come back in send order, so the
        // i-th rx item pairs with the i-th reply on the wire.
        let mut attempts: BTreeMap<usize, usize> = BTreeMap::new();
        let mut pending_retry = 0usize;
        let mut backoff_rng = SplitMix64::new(seed ^ 0xC);
        let mut dead = false;
        for (i, first_sent, failed) in rx {
            let prior = attempts.get(&i).copied().unwrap_or(0);
            if prior == 0 {
                rec.on_send();
            } else {
                pending_retry -= 1; // this scheduled retry made it out
            }
            if failed || dead {
                rec.on_lost();
                dead = true;
                inflight.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            match recv.recv_result() {
                Ok(reply) => {
                    let hint = if reply.ok { 0 } else { reply.retry_after_ms.unwrap_or(0) };
                    if hint > 0 && prior < cfg.retry {
                        attempts.insert(i, prior + 1);
                        rec.on_retry(hint);
                        pending_retry += 1;
                        // Enqueue BEFORE the decrement below — the
                        // sender's shutdown check depends on it.
                        let _ = retry_tx.send((
                            i,
                            Instant::now() + backoff_delay(hint, &mut backoff_rng),
                            first_sent,
                        ));
                    } else {
                        // Latency from the FIRST send: retrying is part
                        // of the round trip the client experienced.
                        rec.on_reply(&reply, first_sent.elapsed());
                        if prior > 0 && !reply.ok && reply.retry_after_ms.is_some() {
                            rec.on_gave_up();
                        }
                    }
                }
                Err(_) => {
                    rec.on_lost();
                    dead = true;
                }
            }
            inflight.fetch_sub(1, Ordering::SeqCst);
        }
        // Retries the sender never resent (dead connection): each was
        // offered once and is still unaccounted — lost, not rejected.
        for _ in 0..pending_retry {
            rec.on_lost();
        }
    });
    let wall = t0.elapsed();
    Ok(Rung {
        label: format!("rate={rate:.1}"),
        offered_rate: rate,
        rec,
        wall,
        metrics: snapshot_metrics(addr),
    })
}

/// One `METRICS` snapshot on a fresh connection, taken right after a
/// rung settles.  Observational only: a snapshot failure degrades to
/// `None` (the rung report just omits the `metrics` block), never to a
/// harness error.
fn snapshot_metrics(addr: &str) -> Option<crate::util::json::Json> {
    let mut client = Client::connect(addr).ok()?;
    client.metrics().ok()
}

/// Suite B: the stochastic open-loop study.  Without `sweep`, one rung
/// at `cfg.rate`; with it, rates multiply by `sweep_factor` rung after
/// rung (each rung re-seeded, so the whole sweep is reproducible) until
/// a rung's reject fraction reaches `stop_reject_frac` — sustained
/// admission rejects, i.e. the saturation knee — or `max_rungs` caps it.
pub fn run_suite_b(addr: &str, cfg: &LoadConfig) -> Result<SuiteReport> {
    let mut rungs = Vec::new();
    let mut rate = cfg.rate.max(0.1);
    let total = if cfg.sweep { cfg.max_rungs.max(1) } else { 1 };
    for i in 0..total {
        let rung = run_rung_b(addr, cfg, rate, i)?;
        let saturated = rung.reject_fraction() >= cfg.stop_reject_frac;
        rungs.push(rung);
        if saturated {
            break;
        }
        rate *= cfg.sweep_factor.max(1.01);
    }
    Ok(SuiteReport { name: "suiteB".into(), seed: cfg.seed, rungs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let cfg = LoadConfig::default();
        assert!(cfg.conns <= cfg.queue_jobs, "suite A must fit the admission queue");
        assert!(cfg.sweep_factor > 1.0 && cfg.stop_reject_frac > 0.0);
        assert!(cfg.rate > 0.0 && !cfg.duration.is_zero());
    }

    #[test]
    fn spawn_fails_fast_on_a_bogus_binary() {
        let cfg = LoadConfig {
            bin: Some("/nonexistent/tetris-load-test".into()),
            ..Default::default()
        };
        assert!(spawn_server(&cfg).is_err());
    }
}
