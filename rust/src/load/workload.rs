//! Workload model for the load harness: a catalog of job kinds and a
//! seeded, weighted sampler over them.
//!
//! A [`JobKind`] fixes everything the server's batch key cares about
//! (bench, shape, boundary, steps) plus the priority class; a [`JobMix`]
//! assigns sampling weights — uniform for the deterministic Suite A
//! baselines, zipfian for the stochastic Suite B mixes, where a heavy
//! head kind exercises session batching and a long tail of cold kinds
//! exercises session churn.  All sampling runs on a caller-provided
//! [`SplitMix64`], so a seed pins the entire job sequence.

use crate::serve::{JobSpec, Priority};
use crate::stencil::Boundary;
use crate::util::prng::SplitMix64;

/// One job template: the (bench, shape, boundary, steps) cell a sampled
/// job lands in, plus its admission priority.
#[derive(Clone, Debug)]
pub struct JobKind {
    pub bench: &'static str,
    pub shape: Vec<usize>,
    pub boundary: Boundary,
    pub steps: usize,
    pub priority: Priority,
}

impl JobKind {
    /// Short label for reports: `heat2d[32x24]/periodic`.
    pub fn label(&self) -> String {
        let dims: Vec<String> = self.shape.iter().map(|n| n.to_string()).collect();
        format!("{}[{}]/{}", self.bench, dims.join("x"), self.boundary)
    }
}

/// A weighted set of job kinds with a seeded sampler.
#[derive(Clone, Debug)]
pub struct JobMix {
    kinds: Vec<JobKind>,
    /// Normalized cumulative weights, `cum[last] == 1.0`.
    cum: Vec<f64>,
}

/// The standard catalog: six kinds across four benches, all three
/// boundary conditions and all three priority classes, with shapes small
/// enough that a single job is milliseconds — the harness measures the
/// serving layer, not the kernels.
pub fn standard_catalog() -> Vec<JobKind> {
    vec![
        JobKind {
            bench: "heat2d",
            shape: vec![32, 24],
            boundary: Boundary::Dirichlet(0.0),
            steps: 8,
            priority: Priority::Normal,
        },
        JobKind {
            bench: "heat2d",
            shape: vec![32, 24],
            boundary: Boundary::Periodic,
            steps: 8,
            priority: Priority::Normal,
        },
        JobKind {
            bench: "heat2d",
            shape: vec![24, 16],
            boundary: Boundary::Dirichlet(25.0),
            steps: 4,
            priority: Priority::Interactive,
        },
        JobKind {
            bench: "heat1d",
            shape: vec![4096],
            boundary: Boundary::Periodic,
            steps: 16,
            priority: Priority::Batch,
        },
        JobKind {
            bench: "heat3d",
            shape: vec![12, 12, 12],
            boundary: Boundary::Neumann,
            steps: 4,
            priority: Priority::Normal,
        },
        JobKind {
            bench: "box2d9p",
            shape: vec![24, 24],
            boundary: Boundary::Dirichlet(0.0),
            steps: 8,
            priority: Priority::Batch,
        },
    ]
}

/// Zipf weights over `n` ranks: `w_i ∝ 1/(i+1)^s`.  `s = 0` is uniform;
/// larger `s` concentrates load on the head kinds.
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect()
}

impl JobMix {
    pub fn new(kinds: Vec<JobKind>, weights: &[f64]) -> JobMix {
        assert!(!kinds.is_empty() && kinds.len() == weights.len());
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must not all be zero");
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for w in weights {
            acc += w / total;
            cum.push(acc);
        }
        *cum.last_mut().unwrap() = 1.0;
        JobMix { kinds, cum }
    }

    /// Uniform weights over the standard catalog (Suite A).
    pub fn standard_uniform() -> JobMix {
        let kinds = standard_catalog();
        let n = kinds.len();
        JobMix::new(kinds, &vec![1.0; n])
    }

    /// Zipfian weights over the standard catalog (Suite B).
    pub fn standard_zipf(s: f64) -> JobMix {
        let kinds = standard_catalog();
        let w = zipf_weights(kinds.len(), s);
        JobMix::new(kinds, &w)
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    pub fn kind(&self, idx: usize) -> &JobKind {
        &self.kinds[idx]
    }

    /// Draw one kind index (the weighted inverse-CDF draw).
    pub fn sample(&self, rng: &mut SplitMix64) -> usize {
        let u = rng.next_f64();
        self.cum.iter().position(|&c| u < c).unwrap_or(self.kinds.len() - 1)
    }

    /// Instantiate kind `idx` as a wire job.
    pub fn spec(&self, idx: usize, id: String, seed: u64) -> JobSpec {
        let k = &self.kinds[idx];
        JobSpec {
            id,
            bench: k.bench.into(),
            boundary: k.boundary,
            steps: k.steps,
            priority: k.priority,
            shape: Some(k.shape.clone()),
            seed,
            field: None,
            return_field: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_kinds_are_valid_benches() {
        for k in standard_catalog() {
            let s = crate::stencil::spec::get(k.bench).expect(k.bench);
            assert_eq!(s.ndim, k.shape.len(), "{}", k.label());
            assert!(k.steps >= 1);
        }
    }

    #[test]
    fn zipf_weights_decay_and_uniform_at_zero() {
        let w = zipf_weights(5, 1.1);
        for pair in w.windows(2) {
            assert!(pair[0] > pair[1], "{w:?}");
        }
        let u = zipf_weights(4, 0.0);
        assert!(u.iter().all(|&x| x == 1.0));
    }

    /// Same seed ⇒ identical job sequence (ids, kinds, everything) —
    /// the determinism contract Suite A is built on.
    #[test]
    fn same_seed_same_job_sequence() {
        let mix = JobMix::standard_zipf(1.1);
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = SplitMix64::new(seed);
            (0..200).map(|_| mix.sample(&mut rng)).collect()
        };
        assert_eq!(draw(42), draw(42));
        assert_ne!(draw(42), draw(43), "different seeds must diverge");
        // the specs built from a fixed sequence are byte-identical
        let idx = draw(7);
        let lines = |seq: &[usize]| -> Vec<String> {
            seq.iter()
                .enumerate()
                .map(|(i, &k)| mix.spec(k, format!("j{i}"), 100 + i as u64).to_json().to_string())
                .collect()
        };
        assert_eq!(lines(&idx), lines(&draw(7)));
    }

    /// With s > 0 the head kind must dominate: empirical frequency of
    /// rank 0 exceeds rank last by a wide margin over 20k draws.
    #[test]
    fn zipf_sampler_tracks_the_weights() {
        let mix = JobMix::standard_zipf(1.2);
        let mut rng = SplitMix64::new(0x10AD);
        let mut counts = vec![0usize; mix.len()];
        let n = 20_000;
        for _ in 0..n {
            counts[mix.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[mix.len() - 1] * 3, "{counts:?}");
        // empirical head frequency within 3 points of the analytic weight
        let w = zipf_weights(mix.len(), 1.2);
        let total: f64 = w.iter().sum();
        let expect = w[0] / total;
        let got = counts[0] as f64 / n as f64;
        assert!((got - expect).abs() < 0.03, "head freq {got} vs {expect}");
    }

    #[test]
    fn spec_carries_the_kind_through() {
        let mix = JobMix::standard_uniform();
        let spec = mix.spec(3, "x".into(), 9);
        assert_eq!(spec.bench, "heat1d");
        assert_eq!(spec.shape.as_deref(), Some(&[4096usize][..]));
        assert_eq!(spec.priority, Priority::Batch);
        assert_eq!(spec.seed, 9);
        assert!(!spec.return_field);
    }
}
