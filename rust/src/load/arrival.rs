//! Arrival processes for open-loop load: seeded Poisson inter-arrival
//! gaps and fixed-horizon schedules built from them.
//!
//! Open-loop means the sender follows the schedule regardless of how the
//! server is doing — unlike a closed loop, a slow server does not slow
//! the offered load down, which is exactly what exposes queueing and
//! admission behavior past saturation.  Everything is driven by a
//! [`SplitMix64`], so a `(rate, seed)` pair pins the whole schedule.

use std::time::Duration;

use crate::util::prng::SplitMix64;

/// Homogeneous Poisson process: exponential inter-arrival gaps with
/// mean `1/rate`.
#[derive(Clone, Debug)]
pub struct Poisson {
    rate_per_sec: f64,
    rng: SplitMix64,
}

impl Poisson {
    pub fn new(rate_per_sec: f64, seed: u64) -> Poisson {
        Poisson { rate_per_sec: rate_per_sec.max(1e-9), rng: SplitMix64::new(seed) }
    }

    /// Next inter-arrival gap: inverse-CDF `-ln(1-u)/rate`, `u∈[0,1)`.
    pub fn next_gap(&mut self) -> Duration {
        let u = self.rng.next_f64();
        Duration::from_secs_f64(-(1.0 - u).ln() / self.rate_per_sec)
    }

    /// Absolute send offsets (from the rung's t=0) covering `horizon`:
    /// strictly non-decreasing, last one `< horizon`.
    pub fn schedule(&mut self, horizon: Duration) -> Vec<Duration> {
        let mut out = Vec::new();
        let mut t = Duration::ZERO;
        loop {
            t += self.next_gap();
            if t >= horizon {
                return out;
            }
            out.push(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same `(rate, seed)` ⇒ identical gap stream and schedule — the
    /// Suite B determinism contract.
    #[test]
    fn same_seed_same_schedule() {
        let horizon = Duration::from_secs(5);
        let a = Poisson::new(40.0, 99).schedule(horizon);
        let b = Poisson::new(40.0, 99).schedule(horizon);
        assert!(!a.is_empty());
        assert_eq!(a, b);
        let c = Poisson::new(40.0, 100).schedule(horizon);
        assert_ne!(a, c, "different seeds must diverge");
    }

    #[test]
    fn schedule_is_sorted_and_inside_horizon() {
        let horizon = Duration::from_millis(800);
        let sched = Poisson::new(200.0, 7).schedule(horizon);
        assert!(sched.windows(2).all(|w| w[0] <= w[1]));
        assert!(sched.iter().all(|&t| t < horizon));
    }

    /// Empirical mean gap within 5% of `1/rate` over 20k draws, and the
    /// gap variance consistent with an exponential (cv ≈ 1), which a
    /// uniform or constant generator would fail.
    #[test]
    fn gaps_are_exponential_with_the_configured_mean() {
        let rate = 250.0;
        let mut p = Poisson::new(rate, 1234);
        let n = 20_000;
        let gaps: Vec<f64> = (0..n).map(|_| p.next_gap().as_secs_f64()).collect();
        let mean = gaps.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.05 / rate, "mean {mean} vs {}", 1.0 / rate);
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n as f64;
        let cv = var.sqrt() / mean;
        assert!((0.9..1.1).contains(&cv), "coefficient of variation {cv} (exponential ⇒ 1)");
    }

    /// Arrival count over a horizon ≈ rate × horizon (±10%).
    #[test]
    fn schedule_count_matches_rate() {
        let sched = Poisson::new(500.0, 42).schedule(Duration::from_secs(20));
        let expect = 500.0 * 20.0;
        assert!(
            (sched.len() as f64 - expect).abs() < 0.10 * expect,
            "{} arrivals vs expected {expect}",
            sched.len()
        );
    }
}
