//! Server-process resource sampling from `/proc/<pid>` (Linux): RSS
//! from `status` and cumulative CPU ticks from `stat`, polled on a
//! background thread while a suite runs.
//!
//! This is observational only — a sample failure (non-Linux host, or
//! the process exiting mid-poll) degrades to "no samples", never to a
//! harness error, so the load report stays usable everywhere and just
//! omits the `proc` block where `/proc` is absent.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Linux kernels report utime/stime in USER_HZ ticks; 100 on every
/// mainstream build (the value is an ABI constant, not a boot option).
const TICKS_PER_SEC: f64 = 100.0;

/// One poll of the server process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProcSample {
    pub rss_bytes: u64,
    /// Cumulative utime+stime ticks since process start.
    pub cpu_ticks: u64,
}

/// Read one [`ProcSample`] for `pid`; `None` when `/proc` is missing or
/// the process is gone.
pub fn sample(pid: u32) -> Option<ProcSample> {
    let status = std::fs::read_to_string(format!("/proc/{pid}/status")).ok()?;
    let rss_kb: u64 = status
        .lines()
        .find(|l| l.starts_with("VmRSS:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()?;
    let stat = std::fs::read_to_string(format!("/proc/{pid}/stat")).ok()?;
    // Fields after the comm's closing paren (comm may contain spaces):
    // state ppid ... with utime at relative index 11, stime at 12.
    let rest = &stat[stat.rfind(')')? + 1..];
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    Some(ProcSample { rss_bytes: rss_kb * 1024, cpu_ticks: utime + stime })
}

/// Aggregate over a monitoring window.
#[derive(Clone, Debug, Default)]
pub struct ProcSummary {
    /// Actual number of samples taken (NOT elapsed/interval: a slow
    /// sampler or a skipped deadline shows up as a smaller count).
    pub samples: usize,
    pub rss_max_bytes: u64,
    pub rss_mean_bytes: u64,
    /// CPU seconds burned between the first and last sample.
    pub cpu_secs: f64,
    /// Configured polling interval (0 when built from raw samples).
    pub interval_ms: f64,
    /// Wall time the monitor ran, start to stop.
    pub elapsed_secs: f64,
}

impl ProcSummary {
    pub fn from_samples(samples: &[ProcSample]) -> ProcSummary {
        if samples.is_empty() {
            return ProcSummary::default();
        }
        let rss_max = samples.iter().map(|s| s.rss_bytes).max().unwrap_or(0);
        let rss_mean = samples.iter().map(|s| s.rss_bytes).sum::<u64>() / samples.len() as u64;
        let ticks = samples.last().unwrap().cpu_ticks.saturating_sub(samples[0].cpu_ticks);
        ProcSummary {
            samples: samples.len(),
            rss_max_bytes: rss_max,
            rss_mean_bytes: rss_mean,
            cpu_secs: ticks as f64 / TICKS_PER_SEC,
            interval_ms: 0.0,
            elapsed_secs: 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("samples".into(), Json::Num(self.samples as f64));
        m.insert("rss_max_bytes".into(), Json::Num(self.rss_max_bytes as f64));
        m.insert("rss_mean_bytes".into(), Json::Num(self.rss_mean_bytes as f64));
        m.insert("cpu_secs".into(), Json::Num(self.cpu_secs));
        m.insert("interval_ms".into(), Json::Num(self.interval_ms));
        m.insert("elapsed_secs".into(), Json::Num(self.elapsed_secs));
        Json::Obj(m)
    }
}

/// Background poller: samples `pid` every `every` until stopped.
pub struct ProcMonitor {
    stop: Arc<AtomicBool>,
    samples: Arc<Mutex<Vec<ProcSample>>>,
    handle: thread::JoinHandle<()>,
    every: Duration,
    started: Instant,
}

impl ProcMonitor {
    pub fn start(pid: u32, every: Duration) -> ProcMonitor {
        Self::start_with(every, move || sample(pid))
    }

    /// Same loop with an injectable sampler, so tests can substitute a
    /// deliberately slow fake and prove the schedule doesn't stretch.
    pub fn start_with(
        every: Duration,
        mut sampler: impl FnMut() -> Option<ProcSample> + Send + 'static,
    ) -> ProcMonitor {
        let every = every.max(Duration::from_millis(1));
        let stop = Arc::new(AtomicBool::new(false));
        let samples = Arc::new(Mutex::new(Vec::new()));
        let (stop2, samples2) = (stop.clone(), samples.clone());
        let started = Instant::now();
        let handle = thread::spawn(move || {
            // Pace against absolute deadlines (start + k*every), like
            // the load generator's arrival schedule: a sampler that
            // takes a sizable fraction of the interval no longer
            // stretches the period (the old sleep-after-work loop ran
            // at `work + every`, under-counting the busiest windows —
            // exactly when samples matter most).  A deadline the
            // sampler overran entirely is skipped, not burst-replayed.
            let start = Instant::now();
            let mut k: u32 = 0;
            while !stop2.load(Ordering::Relaxed) {
                if let Some(s) = sampler() {
                    samples2.lock().unwrap().push(s);
                }
                let now = Instant::now();
                while start + every * (k + 1) <= now {
                    k += 1; // missed deadline: skip it
                }
                k += 1;
                let next = start + every * k;
                // short ticks so stop() returns promptly even for long
                // polling intervals
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        return;
                    }
                    let now = Instant::now();
                    if now >= next {
                        break;
                    }
                    thread::sleep((next - now).min(Duration::from_millis(25)));
                }
            }
        });
        ProcMonitor { stop, samples, handle, every, started }
    }

    /// Stop polling and summarize what was seen.
    pub fn stop(self) -> ProcSummary {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.handle.join();
        let samples = self.samples.lock().unwrap();
        let mut summary = ProcSummary::from_samples(&samples);
        summary.interval_ms = self.every.as_secs_f64() * 1e3;
        summary.elapsed_secs = self.started.elapsed().as_secs_f64();
        summary
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_sample_reads_proc() {
        // On Linux (/proc present) our own process must be sampleable
        // with a real RSS; elsewhere, None is the contract.
        match sample(std::process::id()) {
            Some(s) => assert!(s.rss_bytes > 0, "{s:?}"),
            None => assert!(!cfg!(target_os = "linux"), "sample must work on linux"),
        }
    }

    #[test]
    fn dead_pid_yields_none() {
        // PID 0 never has a /proc entry visible to us.
        assert!(sample(0).is_none());
    }

    #[test]
    fn summary_aggregates() {
        let s = ProcSummary::from_samples(&[
            ProcSample { rss_bytes: 1000, cpu_ticks: 100 },
            ProcSample { rss_bytes: 3000, cpu_ticks: 150 },
            ProcSample { rss_bytes: 2000, cpu_ticks: 400 },
        ]);
        assert_eq!(s.samples, 3);
        assert_eq!(s.rss_max_bytes, 3000);
        assert_eq!(s.rss_mean_bytes, 2000);
        assert!((s.cpu_secs - 3.0).abs() < 1e-9);
        let j = s.to_json();
        assert_eq!(j.at(&["rss_max_bytes"]).as_f64(), Some(3000.0));
    }

    #[test]
    fn empty_summary_is_zeroed() {
        let s = ProcSummary::from_samples(&[]);
        assert_eq!(s.samples, 0);
        assert_eq!(s.to_json().at(&["cpu_secs"]).as_f64(), Some(0.0));
    }

    #[test]
    fn monitor_collects_and_stops() {
        let mon = ProcMonitor::start(std::process::id(), Duration::from_millis(10));
        thread::sleep(Duration::from_millis(80));
        let summary = mon.stop();
        assert!((summary.interval_ms - 10.0).abs() < 1e-9);
        assert!(summary.elapsed_secs >= 0.08, "{summary:?}");
        if cfg!(target_os = "linux") {
            assert!(summary.samples >= 2, "{summary:?}");
            assert!(summary.rss_max_bytes > 0);
        }
    }

    /// Regression for the drift bug: the old loop slept `every` AFTER
    /// each sample, so a sampler taking w ran at period `every + w`.
    /// With an 8ms fake sampler at a 10ms interval over ~500ms, the
    /// drifting loop lands ~28 samples (18ms period); deadline pacing
    /// lands ~50.  The threshold sits between with margin on both sides.
    #[test]
    fn slow_sampler_does_not_stretch_the_period() {
        let mon = ProcMonitor::start_with(Duration::from_millis(10), || {
            thread::sleep(Duration::from_millis(8));
            Some(ProcSample { rss_bytes: 1, cpu_ticks: 0 })
        });
        thread::sleep(Duration::from_millis(500));
        let summary = mon.stop();
        assert!(
            summary.samples >= 35,
            "deadline pacing must absorb sampler latency: {summary:?}"
        );
        assert!(summary.elapsed_secs >= 0.5, "{summary:?}");
    }
}
