//! Suite report codec: one single-line JSON object per suite, shaped so
//! the existing CI tooling (`bench::summary_json` consumers, `tetris
//! bench check`) reads load reports and bench smokes with the same code.
//!
//! The top level *is* a `bench::summary_json` document — `bench`,
//! `scale`, `threads`, `sections` with one `Row` per rung (goodput
//! jobs/sec in `gstencils_per_sec`, as the serve bench already does) —
//! plus two load-specific keys:
//! * `suite` — the full per-rung detail: counts, conservation inputs,
//!   offered/goodput rates, the three latency histograms (p50→p99.9)
//!   and the retry-hint distribution;
//! * `proc`  — RSS/CPU summary of the spawned server process, when the
//!   harness had a pid to watch.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::bench::{summary_json, Row};
use crate::util::json::Json;

use super::recorder::Recorder;
use super::resources::ProcSummary;

/// One measured rung: a (rate, duration) cell of a suite.
#[derive(Debug, Clone)]
pub struct Rung {
    pub label: String,
    /// Configured arrival rate (jobs/sec); 0 for closed-loop rungs.
    pub offered_rate: f64,
    pub rec: Recorder,
    pub wall: Duration,
    /// Server-side `METRICS` snapshot taken right after the rung (flat
    /// `layer.metric -> number`); `None` when the snapshot failed.
    pub metrics: Option<Json>,
}

impl Rung {
    pub fn offered_per_sec(&self) -> f64 {
        self.rec.offered as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn goodput_per_sec(&self) -> f64 {
        self.rec.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn reject_fraction(&self) -> f64 {
        self.rec.rejected as f64 / (self.rec.offered as f64).max(1.0)
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("configured_rate_per_sec".into(), Json::Num(self.offered_rate));
        m.insert("wall_ms".into(), Json::Num(self.wall.as_secs_f64() * 1e3));
        m.insert("offered".into(), Json::Num(self.rec.offered as f64));
        m.insert("completed".into(), Json::Num(self.rec.completed as f64));
        m.insert("rejected".into(), Json::Num(self.rec.rejected as f64));
        m.insert("errors".into(), Json::Num(self.rec.errors as f64));
        m.insert("lost".into(), Json::Num(self.rec.lost as f64));
        m.insert("retried".into(), Json::Num(self.rec.retried as f64));
        m.insert("gave_up".into(), Json::Num(self.rec.gave_up as f64));
        m.insert("offered_per_sec".into(), Json::Num(self.offered_per_sec()));
        m.insert("goodput_per_sec".into(), Json::Num(self.goodput_per_sec()));
        m.insert("reject_fraction".into(), Json::Num(self.reject_fraction()));
        m.insert("latency_ms".into(), self.rec.latency_json());
        m.insert("retry_after_ms".into(), self.rec.retry_hint_json());
        // the client's own view of the rung, in the same flat registry
        // vocabulary as the server-side `metrics` snapshot
        let mut reg = crate::trace::MetricsRegistry::new();
        reg.feed_recorder(&self.rec);
        m.insert("client_metrics".into(), reg.snapshot_json());
        if let Some(metrics) = &self.metrics {
            m.insert("metrics".into(), metrics.clone());
        }
        Json::Obj(m)
    }
}

/// A completed suite: its rungs in execution order.
#[derive(Debug, Clone, Default)]
pub struct SuiteReport {
    /// `"suiteA"` or `"suiteB"` — `bench check` keys its reject
    /// invariant off this name.
    pub name: String,
    pub seed: u64,
    pub rungs: Vec<Rung>,
}

impl SuiteReport {
    /// The whole-suite single-line JSON document (see module docs).
    pub fn to_json(&self, scale: f64, threads: usize, proc: Option<&ProcSummary>) -> Json {
        let rows: Vec<Row> = self
            .rungs
            .iter()
            .map(|r| Row {
                label: r.label.clone(),
                gstencils: r.goodput_per_sec(),
                speedup: r.goodput_per_sec()
                    / self.rungs.first().map(|f| f.goodput_per_sec()).unwrap_or(0.0).max(1e-9),
                extra: format!(
                    "jobs/sec goodput; offered {:.1}/s, {} ok / {} rejected / {} lost, total p99.9 {:.3} ms",
                    r.offered_per_sec(),
                    r.rec.completed,
                    r.rec.rejected,
                    r.rec.lost,
                    r.rec.total.percentile_ms(0.999),
                ),
            })
            .collect();
        let sections = vec![(self.name.clone(), rows)];
        let mut j = summary_json(&format!("serve_{}", self.name), scale, threads, &sections);
        let Json::Obj(top) = &mut j else { unreachable!("summary_json returns an object") };
        let mut suite = BTreeMap::new();
        suite.insert("name".to_string(), Json::Str(self.name.clone()));
        suite.insert("seed".to_string(), Json::Num(self.seed as f64));
        suite.insert(
            "rungs".to_string(),
            Json::Arr(self.rungs.iter().map(Rung::to_json).collect()),
        );
        top.insert("suite".to_string(), Json::Obj(suite));
        if let Some(p) = proc {
            top.insert("proc".to_string(), p.to_json());
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::JobResult;

    fn rung(label: &str, completed: u64, rejected: u64) -> Rung {
        let mut rec = Recorder::new();
        for i in 0..completed + rejected {
            rec.on_send();
            if i < completed {
                let ok = JobResult { ok: true, queue_ms: 0.5, exec_ms: 2.0, ..Default::default() };
                rec.on_reply(&ok, Duration::from_millis(3));
            } else {
                rec.on_reply(&JobResult::reject("j", "full", 75), Duration::from_millis(1));
            }
        }
        Rung {
            label: label.into(),
            offered_rate: 100.0,
            rec,
            wall: Duration::from_millis(500),
            metrics: None,
        }
    }

    #[test]
    fn rung_rates_and_fractions() {
        let r = rung("rate=100", 8, 2);
        assert!((r.offered_per_sec() - 20.0).abs() < 1e-6);
        assert!((r.goodput_per_sec() - 16.0).abs() < 1e-6);
        assert!((r.reject_fraction() - 0.2).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(j.at(&["offered"]).as_usize(), Some(10));
        assert_eq!(j.at(&["retried"]).as_usize(), Some(0));
        assert_eq!(j.at(&["gave_up"]).as_usize(), Some(0));
        assert_eq!(j.at(&["retry_after_ms", "count"]).as_usize(), Some(2));
        assert!(j.at(&["latency_ms", "total", "p999_ms"]).as_f64().unwrap() > 0.0);
        // client-side registry snapshot rides along in the same flat vocabulary
        assert_eq!(j.at(&["client_metrics", "load.completed_total"]).as_usize(), Some(8));
        assert_eq!(j.at(&["client_metrics", "load.rejected_total"]).as_usize(), Some(2));
        assert!(j.get("metrics").is_none(), "no server snapshot attached here");
    }

    #[test]
    fn suite_json_is_single_line_and_bench_compatible() {
        let suite = SuiteReport {
            name: "suiteB".into(),
            seed: 42,
            rungs: vec![rung("rate=100", 10, 0), rung("rate=200", 9, 6)],
        };
        let proc = ProcSummary {
            samples: 4,
            rss_max_bytes: 1 << 20,
            rss_mean_bytes: 1 << 19,
            cpu_secs: 0.5,
            ..Default::default()
        };
        let j = suite.to_json(0.1, 2, Some(&proc));
        let text = j.to_string();
        assert!(!text.contains('\n'));
        let back = Json::parse(&text).unwrap();
        // bench::summary_json shape preserved
        assert_eq!(back.at(&["bench"]).as_str(), Some("serve_suiteB"));
        let rows = back.at(&["sections", "suiteB"]).as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].at(&["label"]).as_str(), Some("rate=100"));
        assert!(rows[0].at(&["extra"]).as_str().unwrap().contains("jobs/sec"));
        // load-specific detail attached
        assert_eq!(back.at(&["suite", "name"]).as_str(), Some("suiteB"));
        assert_eq!(back.at(&["suite", "rungs"]).as_arr().unwrap().len(), 2);
        assert_eq!(back.at(&["proc", "samples"]).as_usize(), Some(4));
    }

    #[test]
    fn suite_json_without_proc_omits_the_block() {
        let suite = SuiteReport { name: "suiteA".into(), seed: 1, rungs: vec![rung("conns=2", 4, 0)] };
        let j = suite.to_json(0.1, 1, None);
        assert!(j.get("proc").is_none());
        assert_eq!(j.at(&["suite", "name"]).as_str(), Some("suiteA"));
    }
}
