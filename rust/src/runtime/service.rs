//! XLA service thread: a single device queue in front of the runtime.
//!
//! The runtime lives on one dedicated thread — the accelerator's command
//! queue, which is the honest model of a real single-GPU deployment (one
//! stream, jobs serialized; a real PJRT client's handles are also not
//! `Send`/`Sync`, so the channel architecture survives the backend swap).
//! Workers submit jobs over an mpsc channel and block on the reply.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};

use crate::stencil::Field;

use super::client::Runtime;
use super::manifest::{ArtifactMeta, BenchMeta, Manifest};

enum Job {
    /// Execute `artifact` on `input`; reply with the output field.
    Run { artifact: String, input: Field, reply: mpsc::Sender<Result<Field>> },
    /// Golden-validate `artifact`; reply with (mean_err, l2_err).
    Validate { artifact: String, reply: mpsc::Sender<Result<(f64, f64)>> },
    Shutdown,
}

/// Cloneable, thread-safe handle to the XLA service thread.
#[derive(Clone)]
pub struct XlaService {
    tx: mpsc::Sender<Job>,
    manifest: Arc<Manifest>,
    // Keep the join handle so the thread is reaped on drop of the last
    // handle; Mutex<Option<..>> because JoinHandle is not Clone.
    join: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
}

impl XlaService {
    /// Spawn the service over the default artifact directory.
    pub fn spawn_default() -> Result<XlaService> {
        Self::spawn(Manifest::load_default()?)
    }

    /// Spawn the service thread; compiles artifacts lazily inside.
    pub fn spawn(manifest: Manifest) -> Result<XlaService> {
        let shared = Arc::new(manifest.clone());
        let (tx, rx) = mpsc::channel::<Job>();
        // Probe: fail fast if the PJRT client cannot start at all.  The
        // real Runtime is constructed inside the thread (not Send).
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let rt = match Runtime::with_manifest(manifest) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Run { artifact, input, reply } => {
                            let res = rt.load(&artifact).and_then(|exe| {
                                if exe.meta.dtype == "f32" {
                                    exe.run_f32(&input)
                                } else {
                                    exe.run(&input)
                                }
                            });
                            let _ = reply.send(res);
                        }
                        Job::Validate { artifact, reply } => {
                            let _ = reply.send(rt.validate(&artifact));
                        }
                        Job::Shutdown => break,
                    }
                }
            })
            .context("spawning xla-service thread")?;
        ready_rx
            .recv()
            .context("xla-service thread died during startup")??;
        Ok(XlaService { tx, manifest: shared, join: Arc::new(Mutex::new(Some(join))) })
    }

    /// Artifact metadata (available without touching the service thread).
    pub fn meta(&self, artifact: &str) -> Result<&ArtifactMeta> {
        self.manifest.artifact(artifact)
    }

    /// Benchmark metadata from the manifest.
    pub fn bench(&self, name: &str) -> Result<&BenchMeta> {
        self.manifest.bench(name)
    }

    /// The full manifest (plain data, shareable).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }

    /// Execute an artifact (blocks until the device queue serves us).
    pub fn run(&self, artifact: &str, input: &Field) -> Result<Field> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Run { artifact: artifact.into(), input: input.clone(), reply })
            .map_err(|_| crate::err!("xla-service thread is gone"))?;
        rx.recv().context("xla-service dropped the reply")?
    }

    /// Golden-validate an artifact.
    pub fn validate(&self, artifact: &str) -> Result<(f64, f64)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Validate { artifact: artifact.into(), reply })
            .map_err(|_| crate::err!("xla-service thread is gone"))?;
        rx.recv().context("xla-service dropped the reply")?
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        // Last handle shuts the thread down.
        if Arc::strong_count(&self.join) == 1 {
            let _ = self.tx.send(Job::Shutdown);
            if let Some(j) = self.join.lock().unwrap().take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service() -> Option<XlaService> {
        for dir in ["artifacts", "../artifacts"] {
            if std::path::Path::new(dir).join("manifest.json").exists() {
                return XlaService::spawn(Manifest::load(dir).unwrap()).ok();
            }
        }
        None
    }

    #[test]
    fn service_runs_artifact() {
        let Some(svc) = service() else { return };
        let meta = svc.meta("heat2d_step").unwrap().clone();
        let input = Field::random(&meta.input_shape, 3);
        let out = svc.run("heat2d_step", &input).unwrap();
        assert_eq!(out.shape(), &meta.output_shape[..]);
    }

    #[test]
    fn service_is_shareable_across_threads() {
        let Some(svc) = service() else { return };
        let meta = svc.meta("heat1d_step").unwrap().clone();
        std::thread::scope(|s| {
            for seed in 0..3u64 {
                let svc = svc.clone();
                let shape = meta.input_shape.clone();
                s.spawn(move || {
                    let input = Field::random(&shape, seed);
                    let out = svc.run("heat1d_step", &input).unwrap();
                    assert!(out.len() > 0);
                });
            }
        });
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(svc) = service() else { return };
        assert!(svc.run("nope", &Field::zeros(&[1])).is_err());
        assert!(svc.meta("nope").is_err());
    }
}
