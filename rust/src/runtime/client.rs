//! Artifact execution — interpreter backend.
//!
//! The original design executed AOT-lowered HLO artifacts through PJRT
//! (the `xla` crate).  That crate is not vendored in this offline build,
//! so the runtime ships an *interpreter* backend instead: every
//! artifact's semantics are fully described by its manifest metadata
//! (bench, variant, fused steps, dtype, shapes), and the in-tree
//! reference oracle executes exactly the same contract —
//!
//! * shrinking artifacts (`output = input - 2*halo`) are valid-mode
//!   Tb-fused blocks (`step`, `block`, `mxu`, `oracle` variants);
//! * shape-preserving artifacts (the `thermal_*` family) are periodic
//!   evolutions;
//! * `f32` artifacts run the true-f32 oracles (`reference::step_f32` /
//!   `reference::evolve_periodic_f32`) — every load, multiply and add
//!   is single precision, the same arithmetic the all-FP32 XLA kernels
//!   perform (paper Table 4).
//!
//! Golden validation (`validate`) regenerates the python-side SplitMix64
//! input stream bit-for-bit, so the cross-language seal still holds.
//! Swapping a real PJRT client back in only touches this file: the
//! [`Executable`] / [`Runtime`] surface is unchanged.

use std::sync::Arc;

use crate::stencil::{reference, spec, Field, StencilSpec};
use crate::util::error::{Context, Result};

use super::manifest::{ArtifactMeta, Manifest};

/// Banded coefficient stack for the trapezoid-folding (MXU) artifacts —
/// rust twin of `python/compile/kernels/mxu_fold.band_matrices`:
/// `bands[dx + r, j + r + dy, j] = c[(dx, dy)]`, shape (2r+1, ny+2r, ny).
pub fn band_matrices(spec: &crate::stencil::StencilSpec, ny: usize) -> Field {
    let r = spec.radius;
    let mut f = Field::zeros(&[2 * r + 1, ny + 2 * r, ny]);
    for (off, c) in &spec.coeffs {
        let (dx, dy) = (off[0], off[1]);
        for j in 0..ny {
            let row = (j as i64 + r as i64 + dy) as usize;
            f.set(&[(dx + r as i64) as usize, row, j], *c);
        }
    }
    f
}

/// Round every cell through f32 (the FP32 storage cast at the artifact
/// boundary; python generates f64 inputs then casts to f32 the same way).
fn round_f32(f: &Field) -> Field {
    Field::from_vec(f.shape(), f.data().iter().map(|&x| x as f32 as f64).collect())
}

/// A loaded artifact ready for execution on the interpreter backend.
pub struct Executable {
    pub meta: ArtifactMeta,
    spec: StencilSpec,
}

impl Executable {
    /// Shape-preserving artifacts evolve periodically; shrinking ones are
    /// valid-mode fused blocks.
    fn periodic(&self) -> bool {
        self.meta.input_shape == self.meta.output_shape
    }

    /// Execute on an f64 field; returns the (single) f64 output field.
    pub fn run(&self, input: &Field) -> Result<Field> {
        crate::ensure!(
            input.shape() == &self.meta.input_shape[..],
            "{}: input shape {:?} != artifact {:?}",
            self.meta.name,
            input.shape(),
            self.meta.input_shape
        );
        if self.periodic() {
            Ok(reference::evolve_periodic(input, &self.spec, self.meta.steps))
        } else {
            Ok(reference::block(input, &self.spec, self.meta.steps))
        }
    }

    /// Execute the f32 variant in true single-precision arithmetic.
    pub fn run_f32(&self, input: &Field) -> Result<Field> {
        crate::ensure!(
            input.shape() == &self.meta.input_shape[..],
            "{}: input shape {:?} != artifact {:?}",
            self.meta.name,
            input.shape(),
            self.meta.input_shape
        );
        if self.periodic() {
            return Ok(reference::evolve_periodic_f32(input, &self.spec, self.meta.steps));
        }
        let mut cur = round_f32(input);
        for _ in 0..self.meta.steps {
            cur = reference::step_f32(&cur, &self.spec);
        }
        Ok(cur)
    }

    /// Execute a stats graph: returns (mean, min, max) of the input.
    pub fn run_stats(&self, input: &Field) -> Result<(f64, f64, f64)> {
        Ok((input.mean(), input.min(), input.max()))
    }
}

/// Manifest-driven artifact loader (interpreter backend).
///
/// Loading is metadata-only, so there is no compile cache; `load` is
/// cheap and the hot path is the block execution itself.
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    /// Runtime over the default artifact directory.
    pub fn new() -> Result<Runtime> {
        Self::with_manifest(Manifest::load_default()?)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Runtime> {
        Ok(Runtime { manifest })
    }

    pub fn platform(&self) -> String {
        "interpreter".to_string()
    }

    /// Load an artifact: resolve its stencil spec from the manifest.
    /// Every artifact aot.py emits carries a bench name (the thermal
    /// family is "heat2d"); an unknown or empty bench is a hard error.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        let meta = self.manifest.artifact(name)?.clone();
        let spec =
            spec::get(&meta.bench).with_context(|| format!("{}: unknown bench {:?}", meta.name, meta.bench))?;
        Ok(Arc::new(Executable { meta, spec }))
    }

    /// Validate one artifact against its golden stats; returns (mean_err,
    /// l2_err) relative errors.
    pub fn validate(&self, name: &str) -> Result<(f64, f64)> {
        let exe = self.load(name)?;
        let meta = &exe.meta;
        let n: usize = meta.input_shape.iter().product();
        let mut rng = crate::util::prng::SplitMix64::new(meta.golden_seed);
        let input = if meta.dtype == "f32" {
            // python generated f64 then cast to f32
            Field::from_vec(&meta.input_shape, rng.fill_f32(n).into_iter().map(|x| x as f64).collect())
        } else {
            Field::from_vec(&meta.input_shape, rng.fill(n))
        };
        let out = if meta.variant == "stats" {
            let (mean, _, _) = exe.run_stats(&input)?;
            // stats artifacts only check the mean
            return Ok((rel_err(mean, meta.golden_mean), 0.0));
        } else if meta.dtype == "f32" {
            exe.run_f32(&input)?
        } else {
            exe.run(&input)?
        };
        Ok((rel_err(out.mean(), meta.golden_mean), rel_err(out.l2(), meta.golden_l2)))
    }
}

fn rel_err(got: f64, want: f64) -> f64 {
    (got - want).abs() / want.abs().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    const SAMPLE: &str = r#"{
      "version": 1,
      "thermal": {"core": [16, 16], "tb": 2},
      "benches": {},
      "artifacts": [
        {"name": "heat2d_step", "file": "heat2d_step.hlo.txt",
         "bench": "heat2d", "variant": "step", "dtype": "f64",
         "steps": 1, "radius": 1, "halo": 1,
         "input_shape": [18, 18], "output_shape": [16, 16],
         "unit_core": [16, 16], "global_core": [16, 16], "tb": 1,
         "golden": {"out_mean": 0.5, "out_l2": 8.0}},
        {"name": "heat2d_block", "file": "heat2d_block.hlo.txt",
         "bench": "heat2d", "variant": "block", "dtype": "f64",
         "steps": 3, "radius": 1, "halo": 3,
         "input_shape": [22, 22], "output_shape": [16, 16],
         "unit_core": [16, 16], "global_core": [16, 16], "tb": 3,
         "golden": {"out_mean": 0.5, "out_l2": 8.0}},
        {"name": "thermal_f32", "file": "thermal_f32.hlo.txt",
         "bench": "heat2d", "variant": "thermal", "dtype": "f32",
         "steps": 2, "radius": 1, "halo": 0,
         "input_shape": [12, 12], "output_shape": [12, 12],
         "unit_core": [12, 12], "global_core": [12, 12], "tb": 2,
         "golden": {"out_mean": 0.5, "out_l2": 8.0}},
        {"name": "benchless", "file": "benchless.hlo.txt",
         "bench": "", "variant": "step", "dtype": "f64",
         "steps": 1, "radius": 1, "halo": 1,
         "input_shape": [6, 6], "output_shape": [4, 4],
         "unit_core": [4, 4], "global_core": [4, 4], "tb": 1,
         "golden": {"out_mean": 0.5, "out_l2": 8.0}}
      ]
    }"#;

    fn runtime() -> Runtime {
        Runtime::with_manifest(Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap()).unwrap()
    }

    #[test]
    fn step_artifact_matches_oracle() {
        let rt = runtime();
        let exe = rt.load("heat2d_step").unwrap();
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[18, 18], 9);
        let got = exe.run(&u).unwrap();
        assert!(got.allclose(&reference::step(&u, &s), 0.0, 0.0));
    }

    #[test]
    fn block_artifact_fuses_steps() {
        let rt = runtime();
        let exe = rt.load("heat2d_block").unwrap();
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[22, 22], 10);
        let got = exe.run(&u).unwrap();
        assert!(got.allclose(&reference::block(&u, &s, 3), 0.0, 0.0));
    }

    #[test]
    fn thermal_f32_is_periodic_and_true_f32() {
        let rt = runtime();
        let exe = rt.load("thermal_f32").unwrap();
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[12, 12], 11);
        let got = exe.run_f32(&u).unwrap();
        assert_eq!(got.shape(), u.shape());
        // exactly the shared true-f32 oracle (same path as apps::accuracy)
        let want = reference::evolve_periodic_f32(&u, &s, 2);
        assert!(got.allclose(&want, 0.0, 0.0));
        // and it drifts from the f64 evolution at single precision
        let d = got.max_abs_diff(&reference::evolve_periodic(&u, &s, 2));
        assert!(d > 0.0 && d < 1e-5, "f32 drift out of range: {d}");
    }

    #[test]
    fn empty_bench_is_rejected() {
        let rt = runtime();
        let err = rt.load("benchless").unwrap_err();
        assert!(err.to_string().contains("unknown bench"), "{err}");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let rt = runtime();
        let exe = rt.load("heat2d_step").unwrap();
        assert!(exe.run(&Field::zeros(&[4, 4])).is_err());
        assert!(exe.run_f32(&Field::zeros(&[4, 4])).is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let rt = runtime();
        assert!(rt.load("nope").is_err());
    }

    #[test]
    fn band_matrices_shape_and_sums() {
        let s = spec::get("heat2d").unwrap();
        let b = band_matrices(&s, 8);
        assert_eq!(b.shape(), &[3, 10, 8]);
        // every coefficient appears once per column: total = ny * sum(c) = ny
        let total: f64 = b.data().iter().sum();
        assert!((total - 8.0).abs() < 1e-12);
    }
}
