//! PJRT execution wrapper: load HLO text artifacts, compile once, execute
//! many times from the L3 hot path.
//!
//! Adapts the pattern of /opt/xla-example/load_hlo: text (not serialized
//! proto) is the interchange format because jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.
//!
//! All lowered functions return 1-tuples (aot.py lowers with
//! `return_tuple=True`), except the stats graphs which return 3-tuples.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::stencil::Field;

use super::manifest::{ArtifactMeta, Manifest};

/// Banded coefficient stack for the trapezoid-folding (MXU) artifacts —
/// rust twin of `python/compile/kernels/mxu_fold.band_matrices`:
/// `bands[dx + r, j + r + dy, j] = c[(dx, dy)]`, shape (2r+1, ny+2r, ny).
pub fn band_matrices(spec: &crate::stencil::StencilSpec, ny: usize) -> Field {
    let r = spec.radius;
    let mut f = Field::zeros(&[2 * r + 1, ny + 2 * r, ny]);
    for (off, c) in &spec.coeffs {
        let (dx, dy) = (off[0], off[1]);
        for j in 0..ny {
            let row = (j as i64 + r as i64 + dy) as usize;
            f.set(&[(dx + r as i64) as usize, row, j], *c);
        }
    }
    f
}

/// A compiled artifact ready for execution.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    /// Pre-marshalled band-stack literal for MXU artifacts (the python
    /// side can't bake it as a constant: the HLO *text* printer elides
    /// large constants, so it travels as a runtime parameter instead).
    bands: Option<xla::Literal>,
}

impl Executable {
    /// Execute on an f64 field; returns the (single) f64 output field.
    pub fn run(&self, input: &Field) -> Result<Field> {
        anyhow::ensure!(
            input.shape() == &self.meta.input_shape[..],
            "{}: input shape {:?} != artifact {:?}",
            self.meta.name,
            input.shape(),
            self.meta.input_shape
        );
        let dims: Vec<i64> = input.shape().iter().map(|&n| n as i64).collect();
        let lit = xla::Literal::vec1(input.data()).reshape(&dims)?;
        let result = match &self.bands {
            Some(b) => self.exe.execute::<xla::Literal>(&[lit, b.clone()])?[0][0]
                .to_literal_sync()?,
            None => self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?,
        };
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f64>()?;
        Ok(Field::from_vec(&self.meta.output_shape, data))
    }

    /// Execute the f32 thermal variant (converting at the boundary).
    pub fn run_f32(&self, input: &Field) -> Result<Field> {
        let dims: Vec<i64> = input.shape().iter().map(|&n| n as i64).collect();
        let f32_data: Vec<f32> = input.data().iter().map(|&x| x as f32).collect();
        let lit = xla::Literal::vec1(&f32_data).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        Ok(Field::from_vec(
            &self.meta.output_shape,
            data.into_iter().map(|x| x as f64).collect(),
        ))
    }

    /// Execute a stats graph: returns (mean, min, max).
    pub fn run_stats(&self, input: &Field) -> Result<(f64, f64, f64)> {
        let dims: Vec<i64> = input.shape().iter().map(|&n| n as i64).collect();
        let (m, lo, hi) = if self.meta.dtype == "f32" {
            let f32_data: Vec<f32> = input.data().iter().map(|&x| x as f32).collect();
            let lit = xla::Literal::vec1(&f32_data).reshape(&dims)?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let (a, b, c) = result.to_tuple3()?;
            (
                a.get_first_element::<f32>()? as f64,
                b.get_first_element::<f32>()? as f64,
                c.get_first_element::<f32>()? as f64,
            )
        } else {
            let lit = xla::Literal::vec1(input.data()).reshape(&dims)?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let (a, b, c) = result.to_tuple3()?;
            (
                a.get_first_element::<f64>()?,
                b.get_first_element::<f64>()?,
                c.get_first_element::<f64>()?,
            )
        };
        Ok((m, lo, hi))
    }
}

/// PJRT client + compiled-executable cache.
///
/// Compilation happens once per artifact (lazily); executions are the
/// hot path.  The cache is behind a mutex so worker threads can share
/// one runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// CPU-PJRT runtime over the default artifact directory.
    pub fn new() -> Result<Runtime> {
        Self::with_manifest(Manifest::load_default()?)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .with_context(|| format!("parsing {:?}", meta.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        // MXU artifacts take the band stack as a second parameter,
        // regenerated here from the spec (see band_matrices).
        let bands = if meta.variant == "mxu" {
            let spec = crate::stencil::spec::get(&meta.bench)
                .with_context(|| format!("{name}: unknown bench {}", meta.bench))?;
            let ny = meta.unit_core[1];
            let b = band_matrices(&spec, ny);
            let dims: Vec<i64> = b.shape().iter().map(|&n| n as i64).collect();
            Some(xla::Literal::vec1(b.data()).reshape(&dims)?)
        } else {
            None
        };
        let arc = std::sync::Arc::new(Executable { exe, meta, bands });
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Validate one artifact against its golden stats; returns (mean_err,
    /// l2_err) relative errors.
    pub fn validate(&self, name: &str) -> Result<(f64, f64)> {
        let exe = self.load(name)?;
        let meta = &exe.meta;
        let n: usize = meta.input_shape.iter().product();
        let mut rng = crate::util::prng::SplitMix64::new(meta.golden_seed);
        let input = if meta.dtype == "f32" {
            // python generated f64 then cast to f32
            Field::from_vec(
                &meta.input_shape,
                rng.fill_f32(n).into_iter().map(|x| x as f64).collect(),
            )
        } else {
            Field::from_vec(&meta.input_shape, rng.fill(n))
        };
        let out = if meta.variant == "stats" {
            let (mean, _, _) = exe.run_stats(&input)?;
            // stats artifacts only check the mean
            return Ok((rel_err(mean, meta.golden_mean), 0.0));
        } else if meta.dtype == "f32" {
            exe.run_f32(&input)?
        } else {
            exe.run(&input)?
        };
        Ok((
            rel_err(out.mean(), meta.golden_mean),
            rel_err(out.l2(), meta.golden_l2),
        ))
    }
}

fn rel_err(got: f64, want: f64) -> f64 {
    (got - want).abs() / want.abs().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        for dir in ["artifacts", "../artifacts"] {
            if std::path::Path::new(dir).join("manifest.json").exists() {
                return Some(Runtime::with_manifest(Manifest::load(dir).unwrap()).unwrap());
            }
        }
        None
    }

    #[test]
    fn golden_validation_heat2d() {
        let Some(rt) = runtime() else { return };
        let (em, el2) = rt.validate("heat2d_step").unwrap();
        assert!(em < 1e-12 && el2 < 1e-12, "mean_err={em} l2_err={el2}");
    }

    #[test]
    fn executable_matches_rust_oracle() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("heat2d_block").unwrap();
        let spec = crate::stencil::spec::get("heat2d").unwrap();
        let input = Field::random(&exe.meta.input_shape, 99);
        let got = exe.run(&input).unwrap();
        let want = crate::stencil::reference::block(&input, &spec, exe.meta.steps);
        assert!(
            got.allclose(&want, 1e-12, 1e-14),
            "maxdiff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("heat2d_step").unwrap();
        assert!(exe.run(&Field::zeros(&[4, 4])).is_err());
    }
}
