//! PJRT runtime: manifest-driven loading and execution of the AOT
//! artifacts produced by `python/compile/aot.py`.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod client;
pub mod manifest;
pub mod service;

pub use client::{Executable, Runtime};
pub use manifest::{ArtifactMeta, BenchMeta, Manifest};
pub use service::XlaService;
