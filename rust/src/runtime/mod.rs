//! Artifact runtime: manifest-driven loading and execution of the AOT
//! artifacts produced by `python/compile/aot.py`.
//!
//! The execution backend is the in-tree interpreter (see
//! [`client`] — the PJRT `xla` crate is not vendored in this offline
//! build); the manifest contract and the device-queue service are
//! identical either way.

pub mod client;
pub mod manifest;
pub mod service;

pub use client::{Executable, Runtime};
pub use manifest::{ArtifactMeta, BenchMeta, Manifest};
pub use service::XlaService;
