//! AOT artifact manifest — the contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! `artifacts/manifest.json` records, per artifact: HLO file, shapes,
//! fused step count, halo width, golden statistics (computed from the
//! SplitMix64 stream both languages implement), and the kernel estimates.
//! Seeds are *recomputed* here from `fnv1a(name)` rather than parsed from
//! JSON, because JSON numbers are f64 and would round 64-bit seeds.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};

use crate::util::json::Json;
use crate::util::prng::fnv1a;

/// One AOT-lowered executable description.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub bench: String,
    pub variant: String,
    pub dtype: String,
    pub steps: usize,
    pub radius: usize,
    pub halo: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub unit_core: Vec<usize>,
    pub global_core: Vec<usize>,
    pub tb: usize,
    pub flops_per_call: f64,
    pub golden_seed: u64,
    pub golden_mean: f64,
    pub golden_l2: f64,
}

/// One benchmark configuration (paper Table 1, scaled).
#[derive(Clone, Debug)]
pub struct BenchMeta {
    pub name: String,
    pub global_core: Vec<usize>,
    pub unit: usize,
    pub tb: usize,
    pub radius: usize,
    pub points: usize,
    pub ndim: usize,
    pub kind: String,
    pub flops_per_cell: usize,
    /// Sorted taps, mirroring spec.py order.
    pub offsets: Vec<Vec<i64>>,
    pub coeffs: Vec<f64>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub benches: BTreeMap<String, BenchMeta>,
    pub thermal_core: Vec<usize>,
    pub thermal_tb: usize,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Default location: `$TETRIS_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("TETRIS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = Json::parse(text).context("manifest")?;
        if v.at(&["version"]).as_usize() != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut artifacts = BTreeMap::new();
        for e in v.at(&["artifacts"]).as_arr().context("artifacts[]")? {
            let name = e.at(&["name"]).as_str().context("name")?.to_string();
            let meta = ArtifactMeta {
                file: dir.join(e.at(&["file"]).as_str().context("file")?),
                bench: e.at(&["bench"]).as_str().unwrap_or("").into(),
                variant: e.at(&["variant"]).as_str().unwrap_or("").into(),
                dtype: e.at(&["dtype"]).as_str().unwrap_or("f64").into(),
                steps: e.at(&["steps"]).as_usize().unwrap_or(1),
                radius: e.at(&["radius"]).as_usize().unwrap_or(0),
                halo: e.at(&["halo"]).as_usize().unwrap_or(0),
                input_shape: e.at(&["input_shape"]).usize_vec().context("input_shape")?,
                output_shape: e.at(&["output_shape"]).usize_vec().context("output_shape")?,
                unit_core: e.at(&["unit_core"]).usize_vec().unwrap_or_default(),
                global_core: e.at(&["global_core"]).usize_vec().unwrap_or_default(),
                tb: e.at(&["tb"]).as_usize().unwrap_or(1),
                flops_per_call: e.at(&["flops_per_call"]).as_f64().unwrap_or(0.0),
                golden_seed: fnv1a(&name),
                golden_mean: e.at(&["golden", "out_mean"]).as_f64().unwrap_or(f64::NAN),
                golden_l2: e.at(&["golden", "out_l2"]).as_f64().unwrap_or(f64::NAN),
                name: name.clone(),
            };
            artifacts.insert(name, meta);
        }
        let mut benches = BTreeMap::new();
        if let Some(obj) = v.at(&["benches"]).as_obj() {
            for (name, b) in obj {
                benches.insert(
                    name.clone(),
                    BenchMeta {
                        name: name.clone(),
                        global_core: b.at(&["global_core"]).usize_vec().context("global_core")?,
                        unit: b.at(&["unit"]).as_usize().context("unit")?,
                        tb: b.at(&["tb"]).as_usize().context("tb")?,
                        radius: b.at(&["radius"]).as_usize().context("radius")?,
                        points: b.at(&["points"]).as_usize().unwrap_or(0),
                        ndim: b.at(&["ndim"]).as_usize().unwrap_or(0),
                        kind: b.at(&["kind"]).as_str().unwrap_or("").into(),
                        flops_per_cell: b.at(&["flops_per_cell"]).as_usize().unwrap_or(0),
                        offsets: b
                            .at(&["offsets"])
                            .as_arr()
                            .map(|a| {
                                a.iter()
                                    .filter_map(|o| {
                                        o.as_arr().map(|xs| {
                                            xs.iter()
                                                .filter_map(|x| x.as_f64().map(|f| f as i64))
                                                .collect()
                                        })
                                    })
                                    .collect()
                            })
                            .unwrap_or_default(),
                        coeffs: b.at(&["coeffs"]).f64_vec().unwrap_or_default(),
                    },
                );
            }
        }
        Ok(Manifest {
            dir,
            artifacts,
            benches,
            thermal_core: v.at(&["thermal", "core"]).usize_vec().unwrap_or_default(),
            thermal_tb: v.at(&["thermal", "tb"]).as_usize().unwrap_or(8),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    pub fn bench(&self, name: &str) -> Result<&BenchMeta> {
        self.benches
            .get(name)
            .with_context(|| format!("bench {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "thermal": {"core": [384, 384], "tb": 8, "mu": 0.23},
      "benches": {
        "heat2d": {"global_core": [256, 256], "unit": 64, "tb": 4,
                    "radius": 1, "points": 5, "ndim": 2, "kind": "star",
                    "flops_per_cell": 10,
                    "offsets": [[-1,0],[0,-1],[0,0],[0,1],[1,0]],
                    "coeffs": [0.23, 0.23, 0.08, 0.23, 0.23]}
      },
      "artifacts": [
        {"name": "heat2d_step", "file": "heat2d_step.hlo.txt",
         "bench": "heat2d", "variant": "step", "dtype": "f64",
         "steps": 1, "radius": 1, "halo": 1,
         "input_shape": [66, 258], "output_shape": [64, 256],
         "unit_core": [64, 256], "global_core": [256, 256], "tb": 4,
         "flops_per_call": 163840,
         "golden": {"seed": 1, "out_mean": 0.5, "out_l2": 64.2,
                     "out_first": 0.1, "out_shape": [64, 256]}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let a = m.artifact("heat2d_step").unwrap();
        assert_eq!(a.input_shape, vec![66, 258]);
        assert_eq!(a.golden_mean, 0.5);
        // seed recomputed from fnv1a, not the json "seed": 1
        assert_eq!(a.golden_seed, fnv1a("heat2d_step"));
        let b = m.bench("heat2d").unwrap();
        assert_eq!(b.unit, 64);
        assert_eq!(b.offsets.len(), 5);
        assert_eq!(m.thermal_core, vec![384, 384]);
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn bad_version_rejected() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration hook: if `make artifacts` has run, parse the real one.
        for dir in ["artifacts", "../artifacts"] {
            if std::path::Path::new(dir).join("manifest.json").exists() {
                let m = Manifest::load(dir).unwrap();
                assert!(m.artifacts.len() >= 20);
                assert_eq!(m.benches.len(), 8);
                for a in m.artifacts.values() {
                    assert!(a.file.exists(), "{:?}", a.file);
                }
            }
        }
    }
}
