//! Crate-wide error handling with zero external crates.
//!
//! The vendored crate set has no `anyhow`, so this module provides the
//! exact subset the stack uses: a string-backed [`TetrisError`], the
//! [`Result`] alias, the [`Context`] extension trait (works on both
//! `Result` and `Option`, like anyhow's), and the `bail!` / `ensure!` /
//! `err!` macros.  Context is accumulated by prefixing messages, which is
//! all the CLI and tests ever inspect.

use std::fmt;

/// Crate-wide error: a human-readable message, grown by `context`.
#[derive(Debug, Clone)]
pub struct TetrisError {
    msg: String,
}

impl TetrisError {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl Into<String>) -> TetrisError {
        TetrisError { msg: msg.into() }
    }
}

impl fmt::Display for TetrisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for TetrisError {}

impl From<std::io::Error> for TetrisError {
    fn from(e: std::io::Error) -> Self {
        TetrisError::msg(e.to_string())
    }
}

impl From<std::fmt::Error> for TetrisError {
    fn from(e: std::fmt::Error) -> Self {
        TetrisError::msg(e.to_string())
    }
}

impl From<crate::util::json::ParseError> for TetrisError {
    fn from(e: crate::util::json::ParseError) -> Self {
        TetrisError::msg(e.to_string())
    }
}

/// Crate-wide result type (error defaults to [`TetrisError`]).
pub type Result<T, E = TetrisError> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| TetrisError::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| TetrisError::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| TetrisError::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| TetrisError::msg(f().to_string()))
    }
}

/// Build a [`TetrisError`] from a format string.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::TetrisError::msg(format!($($t)*))
    };
}

/// Return early with a formatted [`TetrisError`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

/// Return early with a formatted [`TetrisError`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail_test()
    }

    fn bail_test() -> Result<()> {
        crate::bail!("boom {}", 42)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            crate::ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn context_on_result_prefixes() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while writing").unwrap_err();
        assert!(e.to_string().starts_with("while writing: "));
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        let v = Some(7u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn io_error_converts() {
        fn open() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/path")?)
        }
        assert!(open().is_err());
    }

    #[test]
    fn alternate_format_is_stable() {
        // callers print errors with `{e:#}`; Display ignores the flag.
        let e = crate::err!("injected fault");
        assert!(format!("{e:#}").contains("injected fault"));
    }
}
