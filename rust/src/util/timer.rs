//! Timing helpers for the bench harness and the auto-tuner.

use std::time::{Duration, Instant};

/// Time one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Median-of-runs timing: `warmup` discarded runs then `runs` measured.
pub fn time_median<T>(warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Duration {
    for _ in 0..warmup {
        let _ = f();
    }
    let mut samples: Vec<Duration> = (0..runs.max(1))
        .map(|_| {
            let t0 = Instant::now();
            let _ = f();
            t0.elapsed()
        })
        .collect();
    samples.sort();
    samples[samples.len() / 2]
}

/// GStencils/s (paper Eq. 5): cells x steps / seconds / 1e9.
pub fn gstencils_per_sec(cells: usize, steps: usize, d: Duration) -> f64 {
    (cells as f64 * steps as f64) / d.as_secs_f64() / 1e9
}

/// Pretty-print a duration as e.g. "1.234ms".
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gstencils_math() {
        let g = gstencils_per_sec(1_000_000, 10, Duration::from_secs(1));
        assert!((g - 0.01).abs() < 1e-12);
    }

    #[test]
    fn median_is_ordered() {
        let mut i = 0;
        let d = time_median(1, 3, || {
            i += 1;
            std::thread::sleep(Duration::from_micros(10));
        });
        assert!(d >= Duration::from_micros(5));
        assert_eq!(i, 4);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("us"));
    }
}
