//! SplitMix64 PRNG + FNV-1a hashing — bit-identical twins of
//! `python/compile/prng.py` and `aot._seed_for`.
//!
//! The AOT manifest's golden vectors are generated from these streams on
//! the Python side; integration tests regenerate the exact same inputs
//! here, so the artifact numerics are validated end-to-end with no Python
//! on the runtime path.

/// Sebastiano Vigna's splitmix64.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1): top 53 bits / 2^53 (same convention as python).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Row-major buffer of `n` f64 draws.
    pub fn fill(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_f64()).collect()
    }

    /// f32 variant (draws f64 then truncates, matching numpy astype).
    pub fn fill_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f64() as f32).collect()
    }
}

/// FNV-1a 64-bit — mirrors `aot._seed_for`, keyed on artifact names.
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lockstep vectors shared with python/tests/test_prng.py.
    #[test]
    fn seed42_vectors() {
        let mut rng = SplitMix64::new(42);
        assert_eq!(rng.next_u64(), 0xBDD7_3226_2FEB_6E95);
        assert_eq!(rng.next_u64(), 0x28EF_E333_B266_F103);
        assert_eq!(rng.next_u64(), 0x4752_6757_130F_9F52);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = SplitMix64::new(7);
        let xs: Vec<f64> = (0..1000).map(|_| rng.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((0.4..0.6).contains(&mean), "mean {mean}");
    }

    #[test]
    fn fill_deterministic() {
        assert_eq!(SplitMix64::new(123).fill(20), SplitMix64::new(123).fill(20));
    }

    #[test]
    fn fnv1a_vectors() {
        // Same vectors asserted in python/tests/test_aot.py.
        assert_eq!(fnv1a(""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a("a"), 0xAF63_DC4C_8601_EC8C);
        assert_ne!(fnv1a("heat2d_step"), fnv1a("heat2d_block"));
    }
}
