//! Minimal JSON parser/printer for the AOT artifact manifest.
//!
//! The vendored crate set has no `serde`/`serde_json`, so the manifest is
//! parsed with this self-contained recursive-descent implementation.  It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough for any manifest `aot.py` can emit —
//! and pretty-printing for reports the benches write.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        // Manifest seeds are full 64-bit; json numbers are f64, which only
        // holds 53 bits exactly.  aot.py writes seeds as decimal integers;
        // we re-parse them losslessly from the raw token via Str fallback
        // or accept the f64 when exact.
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            Json::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_usize()).collect())
    }

    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        // (surrogate pairs unsupported — manifest is ASCII)
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect raw UTF-8 bytes.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    if c < 0x80 {
                        s.push(c as char);
                        continue;
                    }
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let tok = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": 3.5}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).as_f64(), Some(3.5));
        assert_eq!(
            v.at(&["a"]).as_arr().unwrap()[2].at(&["b"]).as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn as_bool_only_accepts_booleans() {
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("false").unwrap().as_bool(), Some(false));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
        assert_eq!(Json::parse("\"true\"").unwrap().as_bool(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[64, 256]").unwrap();
        assert_eq!(v.usize_vec(), Some(vec![64, 256]));
    }

    #[test]
    fn big_seed_u64() {
        // 2^63 + small — beyond exact f64; as_u64 tolerates manifest seeds
        // because python writes them as integers that f64 may round; the
        // runtime only uses the rounded value via the golden comparison,
        // which was computed from the same parse on the python side? No:
        // python uses exact ints.  Seeds are therefore ALSO recomputed
        // from fnv1a(name) on the rust side rather than trusted from
        // json (see runtime/manifest.rs).
        let v = Json::parse("1234567890123456789").unwrap();
        assert!(v.as_u64().is_some());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
