//! Shared utilities: PRNG (python-lockstep), minimal JSON, timing.

pub mod json;
pub mod prng;
pub mod timer;
