//! Shared utilities: error type, PRNG (python-lockstep), minimal JSON,
//! timing.

pub mod error;
pub mod json;
pub mod prng;
pub mod timer;
